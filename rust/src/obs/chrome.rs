//! Chrome `trace_event` exporter — `slimadam trace export --chrome`.
//!
//! Converts the flight recorder's `trace-<pid>.jsonl` files into the
//! Chrome trace-event JSON format (`{"traceEvents":[...]}`) understood by
//! `chrome://tracing` and Perfetto, so a whole sweep — compiles, dispatch
//! groups, batched steps, evals, store appends — renders as a timeline per
//! worker thread.
//!
//! Input files are read under [`Tolerance::TornTail`]: a SIGKILLed run's
//! torn final line is skipped, everything before it exports.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::runstore::reader::{read_stream_file, scan_jsonl, RowView, Tolerance};

/// Summary of one export pass.
#[derive(Debug, Default)]
pub struct ExportStats {
    pub files: usize,
    pub events: usize,
    pub torn: usize,
}

fn event_from_row(row: &RowView<'_>, pid: u64) -> Option<Value> {
    let kind = row.str("kind")?;
    if kind == "trace_footer" {
        return None;
    }
    let ts = row.f64("ts")?;
    let dur = row.f64("dur").unwrap_or(0.0);
    let tid = row.usize("tid").unwrap_or(0);
    let name = match row.str("name") {
        Some(n) if !n.is_empty() => format!("{kind}:{n}"),
        _ => kind.to_string(),
    };
    let mut ev = Value::obj();
    ev.set("name", name)
        .set("cat", kind)
        // durationless rows become instant events ("i"), spans complete
        // events ("X"); timestamps are microseconds in the chrome format
        .set("ph", if dur > 0.0 { "X" } else { "i" })
        .set("ts", ts / 1e3)
        .set("pid", pid as usize)
        .set("tid", tid);
    if dur > 0.0 {
        ev.set("dur", dur / 1e3);
    } else {
        ev.set("s", "t"); // instant scope: thread
    }
    let mut args = Value::obj();
    for (k, _) in row.fields.iter() {
        let k: &str = k;
        if matches!(k, "kind" | "ts" | "dur" | "tid" | "name") {
            continue;
        }
        if let Some(n) = row.f64(k) {
            args.set(k, n);
        } else if let Some(s) = row.str(k) {
            args.set(k, s);
        }
    }
    ev.set("args", args);
    Some(ev)
}

/// Convert every `trace-*.jsonl` under `dir` into one Chrome trace file at
/// `out`. The `<pid>` in each file name becomes the chrome `pid` so
/// multi-process sweeps stay separable.
pub fn export_dir(dir: &Path, out: &Path) -> Result<ExportStats> {
    let mut stats = ExportStats::default();
    let mut events: Vec<Value> = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading trace dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("trace-") && n.ends_with(".jsonl"))
        })
        .collect();
    entries.sort();
    if entries.is_empty() {
        bail!("no trace-*.jsonl files in {dir:?} — run with --trace first");
    }
    for path in entries {
        stats.files += 1;
        let pid: u64 = path
            .file_stem()
            .and_then(|s| s.to_str())
            .and_then(|s| s.strip_prefix("trace-"))
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let text = read_stream_file(&path)?;
        let scan = scan_jsonl(&text, Tolerance::TornTail, |_, row| {
            if let Some(ev) = event_from_row(&row, pid) {
                events.push(ev);
            }
            Ok(())
        })
        .with_context(|| format!("scanning {path:?}"))?;
        stats.torn += scan.torn;
    }
    stats.events = events.len();
    let mut doc = Value::obj();
    doc.set("traceEvents", Value::Arr(events))
        .set("displayTimeUnit", "ms");
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, doc.dump())
        .with_context(|| format!("writing {out:?}"))?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_tolerates_torn_tail() {
        let dir = std::env::temp_dir()
            .join(format!("slimadam_obs_chrome_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("trace-123.jsonl"),
            "{\"kind\":\"step\",\"ts\":1000.0,\"dur\":500.0,\"tid\":1,\
             \"name\":\"mlp\",\"step\":0}\n\
             {\"kind\":\"cache_hit\",\"ts\":2000.0,\"dur\":0.0,\"tid\":1}\n\
             {\"kind\":\"step\",\"ts\":3000.0,\"du",
        )
        .unwrap();
        let out = dir.join("chrome.json");
        let stats = export_dir(&dir, &out).unwrap();
        assert_eq!((stats.files, stats.events, stats.torn), (1, 2, 1));
        let doc = Value::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        let field = |v: &Value, k: &str| v.get(k).unwrap().clone();
        assert_eq!(field(&evs[0], "name").as_str().unwrap(), "step:mlp");
        assert_eq!(field(&evs[0], "ph").as_str().unwrap(), "X");
        assert_eq!(field(&evs[0], "pid").as_usize().unwrap(), 123);
        assert_eq!(field(&evs[1], "ph").as_str().unwrap(), "i");
        std::fs::remove_dir_all(&dir).ok();
    }
}
