//! Named metrics registry — atomic counters, gauges, and log2 histograms
//! (DESIGN.md §15).
//!
//! Unlike span tracing, the registry is *always on*: a metric update is a
//! single relaxed atomic RMW with no I/O and no allocation after the first
//! lookup, so the scattered ad-hoc counters (`exec_cache` hits/misses,
//! scheduler progress) fold into it without a perf cliff. Call sites cache
//! the `Arc` handle; the global name → metric map is only locked at
//! registration/snapshot time.
//!
//! Snapshots serialize to a flat JSON object (name → value, histograms as
//! `{count, sum, mean, p50, max}`) consumed by `RunSummary.metrics`, the
//! end-of-sweep summary line, and `slimadam obs report`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::json::Value;

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-value-wins signed gauge (queue depths, active workers).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Power-of-two-bucket histogram over `u64` observations (bucket `i`
/// counts values with `ilog2(v) == i`; 0 lands in bucket 0). Cheap enough
/// for per-group batch occupancy and per-step latencies; quantiles are
/// bucket-resolution approximations.
pub struct Histogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let b = if v == 0 { 0 } else { v.ilog2() as usize };
        self.buckets[b.min(63)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Bucket-resolution median: the representative value (2^i) of the
    /// bucket containing the middle observation.
    pub fn p50(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen * 2 >= n {
                return 1u64 << i;
            }
        }
        0
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    static REG: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Get or register the counter `name`. Cache the handle at hot call sites.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut reg = registry().lock().unwrap();
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
    {
        Metric::Counter(c) => c.clone(),
        _ => panic!("metric {name:?} already registered with another type"),
    }
}

/// Get or register the gauge `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut reg = registry().lock().unwrap();
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
    {
        Metric::Gauge(g) => g.clone(),
        _ => panic!("metric {name:?} already registered with another type"),
    }
}

/// Get or register the histogram `name`.
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut reg = registry().lock().unwrap();
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::default())))
    {
        Metric::Histogram(h) => h.clone(),
        _ => panic!("metric {name:?} already registered with another type"),
    }
}

/// Snapshot every registered metric into a flat JSON object. Histograms
/// expand to `<name>` → `{count, sum, mean, p50, max}`.
pub fn snapshot() -> Value {
    let reg = registry().lock().unwrap();
    let mut out = Value::obj();
    for (name, m) in reg.iter() {
        match m {
            Metric::Counter(c) => {
                out.set(name.as_str(), c.get() as usize);
            }
            Metric::Gauge(g) => {
                out.set(name.as_str(), g.get() as f64);
            }
            Metric::Histogram(h) => {
                let mut v = Value::obj();
                v.set("count", h.count() as usize)
                    .set("sum", h.sum() as usize)
                    .set("mean", h.mean())
                    .set("p50", h.p50() as usize)
                    .set("max", h.max() as usize);
                out.set(name.as_str(), v);
            }
        }
    }
    out
}

/// Zero every registered metric (per-sweep scoping, test isolation).
pub fn reset_all() {
    let reg = registry().lock().unwrap();
    for m in reg.values() {
        match m {
            Metric::Counter(c) => c.reset(),
            Metric::Gauge(g) => g.reset(),
            Metric::Histogram(h) => h.reset(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let c = counter("test.reg.counter");
        c.reset();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(Arc::as_ptr(&c), Arc::as_ptr(&counter("test.reg.counter")));
        let g = gauge("test.reg.gauge");
        g.set(-3);
        g.add(5);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn histogram_stats() {
        let h = histogram("test.reg.hist");
        h.reset();
        for v in [1u64, 2, 2, 4, 4, 4, 4, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.sum(), 1021);
        assert!((h.mean() - 127.625).abs() < 1e-9);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.p50(), 4); // middle obs lives in the 4..8 bucket
    }

    #[test]
    fn snapshot_contains_registered_names() {
        counter("test.reg.snap").add(7);
        let snap = snapshot();
        assert!(snap.get("test.reg.snap").unwrap().as_usize().unwrap() >= 7);
    }
}
