//! Work-stealing parallel sweep scheduler (DESIGN.md §9).
//!
//! [`SweepScheduler`] turns a grid of [`TrainConfig`]s into finished
//! [`RunSummary`]s:
//!
//! * **Sharded dispatch** — jobs are assigned to workers by the
//!   `(backend, device, artifact)` they compile under
//!   ([`SweepScheduler::shard_key`]), so each worker's thread-local
//!   executable cache (`exec_cache`) compiles every distinct executable
//!   once even in mixed backend/device pools; idle workers steal across
//!   shards, so a one-artifact sweep still uses the whole pool.
//! * **Batched dispatch** — with [`SweepScheduler::batch`], the batch
//!   planner (`coordinator::batch`, DESIGN.md §12) stacks same-artifact
//!   jobs into lockstep dispatch groups; the pool schedules and steals
//!   whole groups, and per-job rows and fingerprints stay byte-identical
//!   to unbatched runs (`rust/tests/batched_agreement.rs`).
//! * **Streaming results** — with [`SweepScheduler::stream_to`], each job
//!   appends one JSONL row the moment it finishes (tail -f friendly; a
//!   crashed sweep keeps every completed row) instead of reporting at
//!   barrier end. Rows carry the job's grid index, seed, config key and
//!   metrics fingerprint — everything the run store needs to resume.
//! * **Resume** — with [`SweepScheduler::resume_from`], the scheduler
//!   consults a [`RunIndex`] before dispatch and skips every config whose
//!   key is already stored, restoring its summary from the streamed row
//!   (DESIGN.md §10). Skipped jobs re-execute nothing; the skip/ran/total
//!   summary is printed at barrier end.
//! * **Scheduling-invariant metrics** — every job's result is a pure
//!   function of its config; seeds come from the config (or, with
//!   [`SweepScheduler::run_seeded`], from `rng::job_seed(base, index)`),
//!   never from worker identity. Serial and parallel runs of the same
//!   grid are byte-identical, job for job.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::metrics::JsonlWriter;
use crate::obs::{self, registry, SpanKind};
use crate::pool::{default_workers, parallel_map_sharded};
use crate::rng::{job_seed, stable_hash64};
use crate::runstore::{config_key, RunIndex, RunStore};

use super::{exec_cache, EngineKind, RunSummary, TrainConfig};

/// Parallel sweep scheduler; build with [`SweepScheduler::new`], then
/// chain [`stream_to`](SweepScheduler::stream_to) /
/// [`resume_from`](SweepScheduler::resume_from) /
/// [`batch`](SweepScheduler::batch) /
/// [`quiet`](SweepScheduler::quiet) and call [`run`](SweepScheduler::run).
#[derive(Debug, Default)]
pub struct SweepScheduler {
    workers: usize,
    stream: Option<PathBuf>,
    resume: Option<RunIndex>,
    quiet: bool,
    batch: usize,
}

impl SweepScheduler {
    /// `workers == 0` means one worker per core (capped by job count).
    pub fn new(workers: usize) -> SweepScheduler {
        SweepScheduler {
            workers,
            stream: None,
            resume: None,
            quiet: false,
            batch: 1,
        }
    }

    /// Stack up to `n` same-artifact jobs into one backend dispatch per
    /// training step (DESIGN.md §12). Jobs are grouped by the batch
    /// planner's feasibility key (`coordinator::batch`); the work units
    /// the pool schedules — and idle workers steal — become whole
    /// groups, so a stolen group keeps its one-dispatch property.
    /// Results are bit-identical to `batch(1)`
    /// (`rust/tests/batched_agreement.rs`). `n <= 1` means unbatched.
    pub fn batch(mut self, n: usize) -> SweepScheduler {
        self.batch = n.max(1);
        self
    }

    /// Append one JSONL row per job to `path` as jobs finish. Rows carry
    /// the job's grid index, seed, config key and metrics fingerprint, so
    /// partial sweeps are resumable/diffable.
    pub fn stream_to(mut self, path: impl Into<PathBuf>) -> SweepScheduler {
        self.stream = Some(path.into());
        self
    }

    /// Resume against `store`: repair torn tails, build the run index,
    /// and skip every config already completed. Pair with
    /// [`stream_to`](SweepScheduler::stream_to) pointing into the same
    /// store so newly finished jobs extend it.
    pub fn resume_from(self, store: &RunStore) -> Result<SweepScheduler> {
        store.repair_tails()?;
        let index = store.index()?;
        if !self.quiet && index.stats.legacy > 0 {
            eprintln!(
                "  resume: {} row(s) in the store carry no config key \
                 (pre-runstore streams) and cannot be matched",
                index.stats.legacy
            );
        }
        Ok(self.resume_index(index))
    }

    /// Resume against an already-built [`RunIndex`].
    pub fn resume_index(mut self, index: RunIndex) -> SweepScheduler {
        self.resume = Some(index);
        self
    }

    /// Suppress the per-job progress lines on stderr.
    pub fn quiet(mut self) -> SweepScheduler {
        self.quiet = true;
        self
    }

    /// The artifact a config will compile.
    pub fn artifact_key(cfg: &TrainConfig) -> String {
        match &cfg.engine {
            EngineKind::Split => format!("{}.grad", cfg.model),
            EngineKind::Fused(ruleset) => format!("{}.train.{ruleset}", cfg.model),
        }
    }

    /// The scheduler's shard key: `(backend, device, artifact)` — the
    /// executable-cache identity a job will compile under (DESIGN.md §11),
    /// so same-compilation jobs land on the same worker's cache even in
    /// mixed backend/device pools.
    pub fn shard_key(cfg: &TrainConfig) -> String {
        format!("{}|{}", cfg.backend.key(), Self::artifact_key(cfg))
    }

    /// One streamed result row: the summary JSON plus the job's grid
    /// index, seed, config key and metrics fingerprint — everything the
    /// run store needs to resume. Shared by the CLI sweep path and the
    /// serve daemon (`crate::serve`), which is what makes a daemon-run
    /// sweep's rows byte-identical to the one-shot CLI run's.
    pub fn summary_row(
        cfg: &TrainConfig,
        summary: &RunSummary,
        job: usize,
    ) -> crate::json::Value {
        let mut row = summary.to_json();
        row.set("job", job)
            .set("seed", format!("{:016x}", cfg.seed))
            .set("config_key", format!("{:016x}", config_key(cfg)))
            .set(
                "fingerprint",
                format!("{:016x}", summary.result.fingerprint()),
            );
        row
    }

    /// Run every config; summaries return in input order. Worker count
    /// and batch size never change results
    /// (`rust/tests/scheduler_determinism.rs`,
    /// `rust/tests/batched_agreement.rs`), and with resume active,
    /// neither does skipping: restored summaries occupy their original
    /// grid slots.
    pub fn run(&self, configs: &[TrainConfig]) -> Result<Vec<RunSummary>> {
        let total = configs.len();
        let keys: Vec<u64> = configs.iter().map(config_key).collect();
        let cache_before = exec_cache::stats();
        let steals = registry::counter("pool.steals");
        let steals_before = steals.get();
        let occupancy = registry::histogram("batch.occupancy");
        let occ_before = (occupancy.count(), occupancy.sum());
        let jobs_run = registry::counter("sweep.jobs_run");
        let jobs_skipped = registry::counter("sweep.jobs_skipped");

        // Restore already-completed jobs up front; only the remainder is
        // planned into dispatch groups.
        let mut slots: Vec<Option<RunSummary>> = (0..total).map(|_| None).collect();
        let mut pending: Vec<usize> = Vec::with_capacity(total);
        let mut skipped = 0usize;
        for i in 0..total {
            if let Some(index) = &self.resume {
                if let Some(entry) = index.get(keys[i]) {
                    // Already computed: restore from the store, write no
                    // row (its row is what we restored from).
                    slots[i] = Some(entry.to_summary());
                    skipped += 1;
                    obs::emit_instant(
                        SpanKind::ResumeSkip,
                        obs::NO_LABEL,
                        [i as u64, 0, 0, 0],
                    );
                    continue;
                }
            }
            pending.push(i);
        }
        jobs_skipped.add(skipped as u64);
        if self.resume.is_some() && !self.quiet {
            eprintln!("  resume: {skipped}/{total} jobs already in the run store");
        }

        // The pool's work units are dispatch groups: singletons when
        // unbatched, planner output otherwise. Stealing moves whole
        // groups, so a stolen group keeps its one-dispatch property.
        let plan_t0 = obs::clock();
        let groups: Vec<Vec<usize>> = if self.batch <= 1 {
            pending.iter().map(|&i| vec![i]).collect()
        } else {
            super::batch::plan(configs, &pending, self.batch)
        };
        for group in &groups {
            occupancy.observe(group.len() as u64);
            if obs::enabled() {
                obs::emit_since(
                    SpanKind::PlanGroup,
                    obs::intern(&Self::shard_key(&configs[group[0]])),
                    plan_t0,
                    [group.len() as u64, self.batch as u64, 0, 0],
                );
            }
        }
        let workers = if self.workers == 0 {
            default_workers(groups.len())
        } else {
            self.workers
        };
        registry::gauge("sweep.queue_depth").set(groups.len() as i64);

        // Append, never truncate: a crashed sweep keeps every completed
        // row, which is what makes the streamed file resumable/diffable.
        let sink: Option<Mutex<JsonlWriter>> = match &self.stream {
            Some(path) => Some(Mutex::new(JsonlWriter::append(path)?)),
            None => None,
        };
        let done = AtomicUsize::new(skipped);
        let results = parallel_map_sharded(
            &groups,
            workers,
            |_, group| stable_hash64(Self::shard_key(&configs[group[0]]).as_bytes()),
            |_, group| {
                // run_group attaches the failing job's label (or the whole
                // group's labels on a batched failure) to its errors.
                let summaries = super::batch::run_group(configs, group)?;
                if !self.quiet {
                    for summary in &summaries {
                        let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                        eprintln!(
                            "  [{n}/{total}] {:40} loss={:.4} eval={:.4}{}",
                            summary.label,
                            summary.result.final_train_loss,
                            summary.result.eval_loss,
                            if summary.result.diverged { "  DIVERGED" } else { "" }
                        );
                    }
                } else {
                    done.fetch_add(group.len(), Ordering::Relaxed);
                }
                if let Some(writer) = &sink {
                    // One lock acquisition per group: a group's rows land
                    // contiguously, so concurrent workers interleave only
                    // at row granularity — and the run index is append-
                    // order-agnostic anyway (rust/tests/runstore_resume.rs
                    // covers interleaved and torn-mid-batch orders).
                    let mut writer = writer.lock().unwrap();
                    for (&i, summary) in group.iter().zip(&summaries) {
                        let row = Self::summary_row(&configs[i], summary, i);
                        let append_t0 = obs::clock();
                        writer.write(&row)?;
                        obs::emit_since(
                            SpanKind::StoreAppend,
                            obs::NO_LABEL,
                            append_t0,
                            [i as u64, 0, 0, 0],
                        );
                    }
                }
                jobs_run.add(group.len() as u64);
                registry::gauge("sweep.queue_depth").add(-1);
                Ok(summaries)
            },
        )?;
        for (group, summaries) in groups.iter().zip(results) {
            for (&i, summary) in group.iter().zip(summaries) {
                slots[i] = Some(summary);
            }
        }
        if self.resume.is_some() && !self.quiet {
            eprintln!(
                "  sweep: ran {}, skipped {skipped}, total {total}",
                total - skipped
            );
        }
        if !self.quiet {
            // One structured end-of-sweep summary line (machine-greppable
            // JSON) in place of the old scattered cache/steal prints. The
            // cache and steal figures are deltas over this run() call, so
            // back-to-back sweeps in one process report their own work.
            let cache_after = exec_cache::stats();
            let mut s = crate::json::Value::obj();
            s.set("ran", total - skipped)
                .set("skipped", skipped)
                .set("total", total)
                .set("groups", groups.len())
                .set("workers", workers)
                .set(
                    "cache_hits",
                    cache_after.hits.saturating_sub(cache_before.hits) as usize,
                )
                .set(
                    "cache_compiles",
                    cache_after.misses.saturating_sub(cache_before.misses) as usize,
                )
                .set(
                    "steals",
                    steals.get().saturating_sub(steals_before) as usize,
                )
                .set("batch_occupancy_mean", {
                    let n = occupancy.count().saturating_sub(occ_before.0);
                    let sum = occupancy.sum().saturating_sub(occ_before.1);
                    if n == 0 { 0.0 } else { sum as f64 / n as f64 }
                });
            eprintln!("  sweep summary: {}", s.dump());
        }
        Ok(slots
            .into_iter()
            .map(|s| s.expect("every job produced a summary"))
            .collect())
    }

    /// Like [`SweepScheduler::run`], but job `i` trains with the derived
    /// seed `rng::job_seed(base_seed, i)`: independent draws per grid
    /// point that remain a pure function of grid position, so replicate
    /// sweeps stay scheduling-invariant (and resumable — the config key
    /// hashes the derived seed).
    pub fn run_seeded(
        &self,
        configs: &[TrainConfig],
        base_seed: u64,
    ) -> Result<Vec<RunSummary>> {
        let seeded: Vec<TrainConfig> = configs
            .iter()
            .enumerate()
            .map(|(i, cfg)| {
                let mut cfg = cfg.clone();
                cfg.seed = job_seed(base_seed, i as u64);
                cfg
            })
            .collect();
        self.run(&seeded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_keys_follow_engine_kind() {
        let mut cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 10);
        assert_eq!(SweepScheduler::artifact_key(&cfg), "gpt_nano.grad");
        cfg.engine = EngineKind::Fused("slimadam".into());
        assert_eq!(
            SweepScheduler::artifact_key(&cfg),
            "gpt_nano.train.slimadam"
        );
    }

    #[test]
    fn shard_keys_separate_backends_and_devices() {
        use crate::runtime::backend::BackendSpec;
        let cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 10);
        assert_eq!(
            SweepScheduler::shard_key(&cfg),
            "pjrt@cpu:0|gpt_nano.grad"
        );
        let mut native = cfg.clone();
        native.backend = BackendSpec::native();
        assert_ne!(
            SweepScheduler::shard_key(&cfg),
            SweepScheduler::shard_key(&native)
        );
        let mut gpu = cfg.clone();
        gpu.backend = BackendSpec::parse("pjrt@gpu:1").unwrap();
        assert_eq!(SweepScheduler::shard_key(&gpu), "pjrt@gpu:1|gpt_nano.grad");
    }

    #[test]
    fn run_seeded_derives_distinct_pure_seeds() {
        let base = TrainConfig::lm("gpt_nano", "adam", 1e-3, 10);
        let configs = vec![base.clone(), base.clone(), base];
        // seeds are injected before any job runs; verify via the pure
        // derivation rather than executing (no artifacts needed)
        let s0 = crate::rng::job_seed(7, 0);
        let s1 = crate::rng::job_seed(7, 1);
        assert_ne!(s0, s1);
        assert_eq!(s0, crate::rng::job_seed(7, 0));
        assert_eq!(configs.len(), 3);
    }

    #[test]
    fn empty_resume_index_skips_nothing() {
        // an empty index must leave the skip mask all-false; full
        // resume-cycle coverage lives in rust/tests/runstore_resume.rs
        let index = RunIndex::new();
        let configs = vec![
            TrainConfig::lm("gpt_nano", "adam", 1e-3, 10),
            TrainConfig::lm("gpt_nano", "adam", 3e-3, 10),
        ];
        assert_eq!(index.skip_mask(&configs), vec![false, false]);
    }
}
