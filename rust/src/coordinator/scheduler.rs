//! Work-stealing parallel sweep scheduler (DESIGN.md §9).
//!
//! [`SweepScheduler`] turns a grid of [`TrainConfig`]s into finished
//! [`RunSummary`]s:
//!
//! * **Sharded dispatch** — jobs are assigned to workers by the artifact
//!   they compile ([`SweepScheduler::artifact_key`]), so each worker's
//!   thread-local executable cache (`exec_cache`) compiles every distinct
//!   artifact once; idle workers steal across shards, so a one-artifact
//!   sweep still uses the whole pool.
//! * **Streaming results** — with [`SweepScheduler::stream_to`], each job
//!   appends one JSONL row the moment it finishes (tail -f friendly; a
//!   crashed sweep keeps every completed row) instead of reporting at
//!   barrier end.
//! * **Scheduling-invariant metrics** — every job's result is a pure
//!   function of its config; seeds come from the config (or, with
//!   [`SweepScheduler::run_seeded`], from `rng::job_seed(base, index)`),
//!   never from worker identity. Serial and parallel runs of the same
//!   grid are byte-identical, job for job.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::metrics::JsonlWriter;
use crate::pool::{default_workers, parallel_map_sharded};
use crate::rng::{job_seed, stable_hash64};

use super::{run_config, EngineKind, RunSummary, TrainConfig};

/// Parallel sweep scheduler; build with [`SweepScheduler::new`], then
/// chain [`stream_to`](SweepScheduler::stream_to) /
/// [`quiet`](SweepScheduler::quiet) and call [`run`](SweepScheduler::run).
#[derive(Debug, Default)]
pub struct SweepScheduler {
    workers: usize,
    stream: Option<PathBuf>,
    quiet: bool,
}

impl SweepScheduler {
    /// `workers == 0` means one worker per core (capped by job count).
    pub fn new(workers: usize) -> SweepScheduler {
        SweepScheduler {
            workers,
            stream: None,
            quiet: false,
        }
    }

    /// Append one JSONL row per job to `path` as jobs finish. Rows carry
    /// the job's grid index and a metrics fingerprint, so partial sweeps
    /// are resumable/diffable.
    pub fn stream_to(mut self, path: impl Into<PathBuf>) -> SweepScheduler {
        self.stream = Some(path.into());
        self
    }

    /// Suppress the per-job progress lines on stderr.
    pub fn quiet(mut self) -> SweepScheduler {
        self.quiet = true;
        self
    }

    /// The artifact a config will compile — the scheduler's shard key, so
    /// same-artifact jobs land on the same worker's executable cache.
    pub fn artifact_key(cfg: &TrainConfig) -> String {
        match &cfg.engine {
            EngineKind::Split => format!("{}.grad", cfg.model),
            EngineKind::Fused(ruleset) => format!("{}.train.{ruleset}", cfg.model),
        }
    }

    /// Run every config; summaries return in input order. Worker count
    /// never changes results (`rust/tests/scheduler_determinism.rs`).
    pub fn run(&self, configs: &[TrainConfig]) -> Result<Vec<RunSummary>> {
        let total = configs.len();
        let workers = if self.workers == 0 {
            default_workers(total)
        } else {
            self.workers
        };
        // Append, never truncate: a crashed sweep keeps every completed
        // row, which is what makes the streamed file resumable/diffable.
        let sink: Option<Mutex<JsonlWriter>> = match &self.stream {
            Some(path) => Some(Mutex::new(JsonlWriter::append(path)?)),
            None => None,
        };
        let done = AtomicUsize::new(0);
        parallel_map_sharded(
            configs,
            workers,
            |_, cfg| stable_hash64(Self::artifact_key(cfg).as_bytes()),
            |i, cfg| {
                let summary =
                    run_config(cfg).map_err(|e| anyhow!("{}: {e}", cfg.label()))?;
                let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                if !self.quiet {
                    eprintln!(
                        "  [{n}/{total}] {:40} loss={:.4} eval={:.4}{}",
                        summary.label,
                        summary.result.final_train_loss,
                        summary.result.eval_loss,
                        if summary.result.diverged { "  DIVERGED" } else { "" }
                    );
                }
                if let Some(writer) = &sink {
                    let mut row = summary.to_json();
                    row.set("job", i).set(
                        "fingerprint",
                        format!("{:016x}", summary.result.fingerprint()),
                    );
                    writer.lock().unwrap().write(&row)?;
                }
                Ok(summary)
            },
        )
    }

    /// Like [`SweepScheduler::run`], but job `i` trains with the derived
    /// seed `rng::job_seed(base_seed, i)`: independent draws per grid
    /// point that remain a pure function of grid position, so replicate
    /// sweeps stay scheduling-invariant.
    pub fn run_seeded(
        &self,
        configs: &[TrainConfig],
        base_seed: u64,
    ) -> Result<Vec<RunSummary>> {
        let seeded: Vec<TrainConfig> = configs
            .iter()
            .enumerate()
            .map(|(i, cfg)| {
                let mut cfg = cfg.clone();
                cfg.seed = job_seed(base_seed, i as u64);
                cfg
            })
            .collect();
        self.run(&seeded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_keys_follow_engine_kind() {
        let mut cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 10);
        assert_eq!(SweepScheduler::artifact_key(&cfg), "gpt_nano.grad");
        cfg.engine = EngineKind::Fused("slimadam".into());
        assert_eq!(
            SweepScheduler::artifact_key(&cfg),
            "gpt_nano.train.slimadam"
        );
    }

    #[test]
    fn run_seeded_derives_distinct_pure_seeds() {
        let base = TrainConfig::lm("gpt_nano", "adam", 1e-3, 10);
        let configs = vec![base.clone(), base.clone(), base];
        // seeds are injected before any job runs; verify via the pure
        // derivation rather than executing (no artifacts needed)
        let s0 = crate::rng::job_seed(7, 0);
        let s1 = crate::rng::job_seed(7, 1);
        assert_ne!(s0, s1);
        assert_eq!(s0, crate::rng::job_seed(7, 0));
        assert_eq!(configs.len(), 3);
    }
}
