//! Compile-once executable cache (DESIGN.md §9, §11).
//!
//! Compiled executables are thread-confined (the PJRT wrapper types are
//! not `Send`), so each worker thread owns its backends
//! ([`thread_backend`]) plus a thread-local cache of compiled executables
//! keyed by `(backend, device, artifact name, manifest hash)`. A 50-point
//! LR sweep on a 4-worker pool therefore compiles each distinct artifact
//! at most 4 times (once per worker that touches it) instead of 50 — and
//! because the sweep scheduler shards jobs by the same backend+artifact
//! key (`SweepScheduler::shard_key`), usually exactly once.
//!
//! Keying on the manifest hash, not just the name, means re-running
//! `make artifacts` mid-process can never serve a stale executable: a
//! re-lowered artifact has a new manifest digest and misses the cache.
//! Keying on `(backend, device)` means a mixed pool — PJRT artifacts next
//! to native interpreter runs, or (later) CPU next to GPU clients — never
//! cross-serves an executable compiled for a different engine.
//!
//! The global [`stats`] counters aggregate hits/misses across all worker
//! threads so tests and benches can assert the compile-once property.
//! They live in the observability metrics registry (`exec_cache.hits` /
//! `exec_cache.misses` — DESIGN.md §15), so the same numbers reach the
//! end-of-sweep summary line, `RunSummary.metrics`, and `slimadam obs
//! report` without any ad-hoc printing here. Cache lookups additionally
//! emit `cache_hit` / `cache_miss` / `compile` spans when tracing is live.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::obs::{self, registry, SpanKind};
use crate::runtime::backend::{backend_for, Backend, BackendSpec};
use crate::runtime::engine::{Compiled, GradEngine};

fn hits() -> &'static Arc<registry::Counter> {
    static C: OnceLock<Arc<registry::Counter>> = OnceLock::new();
    C.get_or_init(|| registry::counter("exec_cache.hits"))
}

fn misses() -> &'static Arc<registry::Counter> {
    static C: OnceLock<Arc<registry::Counter>> = OnceLock::new();
    C.get_or_init(|| registry::counter("exec_cache.misses"))
}

/// Snapshot of the global cache counters (all worker threads combined).
/// Every miss is exactly one backend compilation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Compilations performed (alias for `misses`, named for intent).
    pub fn compiles(&self) -> u64 {
        self.misses
    }
}

/// Read the global hit/miss counters.
pub fn stats() -> CacheStats {
    CacheStats {
        hits: hits().get(),
        misses: misses().get(),
    }
}

/// Zero the global counters (tests and benches bracket sweeps with this).
pub fn reset_stats() {
    hits().reset();
    misses().reset();
}

/// Record a cache hit (instant span + counter).
fn note_hit(name: &str) {
    hits().inc();
    if obs::enabled() {
        obs::emit_instant(SpanKind::CacheHit, obs::intern(name), [0; 4]);
    }
}

/// Record a cache miss; returns a [`obs::clock`] mark so the caller can
/// close the `compile` span over the actual compilation.
fn note_miss(name: &str) -> u64 {
    misses().inc();
    if obs::enabled() {
        obs::emit_instant(SpanKind::CacheMiss, obs::intern(name), [0; 4]);
    }
    obs::clock()
}

/// Intern a span label only when tracing is live.
fn obs_label(name: &str) -> u32 {
    if obs::enabled() {
        obs::intern(name)
    } else {
        obs::NO_LABEL
    }
}

/// Cache key: execution identity (backend kind + device) plus artifact
/// identity (name + manifest digest).
type Key = (BackendSpec, String, u64);

thread_local! {
    static BACKENDS: RefCell<HashMap<BackendSpec, Rc<dyn Backend>>> =
        RefCell::new(HashMap::new());
    static GRAD: RefCell<HashMap<Key, Rc<GradEngine>>> =
        RefCell::new(HashMap::new());
    static TRAIN: RefCell<HashMap<Key, Rc<Compiled>>> =
        RefCell::new(HashMap::new());
}

/// This worker thread's backend for `spec`, created on first use. One
/// backend instance per worker is the threading contract here: the PJRT
/// wrapper types are not `Send`, and a CPU client is cheap; the native
/// interpreter is stateless.
pub fn thread_backend(spec: &BackendSpec) -> Result<Rc<dyn Backend>> {
    BACKENDS.with(|slot| {
        if let Some(backend) = slot.borrow().get(spec) {
            return Ok(backend.clone());
        }
        let backend = backend_for(spec)?;
        slot.borrow_mut().insert(*spec, backend.clone());
        Ok(backend)
    })
}

/// Cached split engine for `<model>.grad` on the given backend: compiled
/// at most once per worker thread per `(backend, device, manifest)`.
pub fn grad_engine(spec: &BackendSpec, dir: &str, model: &str) -> Result<Rc<GradEngine>> {
    let name = format!("{model}.grad");
    let backend = thread_backend(spec)?;
    let art = backend.load_artifact(dir.as_ref(), &name)?;
    let key = (*spec, name, art.manifest_hash);
    GRAD.with(|cache| {
        if let Some(engine) = cache.borrow().get(&key) {
            note_hit(&key.1);
            return Ok(engine.clone());
        }
        let t0 = note_miss(&key.1);
        let engine = Rc::new(GradEngine::from_artifact(&art, backend.as_ref())?);
        obs::emit_since(SpanKind::Compile, obs_label(&key.1), t0, [0; 4]);
        cache.borrow_mut().insert(key, engine.clone());
        Ok(engine)
    })
}

/// Cached compiled fused train-step executable `<model>.train.<ruleset>`.
/// The caller wraps it in a fresh `TrainEngine` per run (state is per-run;
/// the compilation is what's expensive and shareable).
pub fn train_compiled(
    spec: &BackendSpec,
    dir: &str,
    model: &str,
    ruleset: &str,
) -> Result<Rc<Compiled>> {
    let name = format!("{model}.train.{ruleset}");
    let backend = thread_backend(spec)?;
    let art = backend.load_artifact(dir.as_ref(), &name)?;
    anyhow::ensure!(
        art.manifest.kind == "train_step",
        "artifact {} is not a train_step",
        name
    );
    let key = (*spec, name, art.manifest_hash);
    TRAIN.with(|cache| {
        if let Some(compiled) = cache.borrow().get(&key) {
            note_hit(&key.1);
            return Ok(compiled.clone());
        }
        let t0 = note_miss(&key.1);
        let compiled = Rc::new(art.compile(backend.as_ref())?);
        obs::emit_since(SpanKind::Compile, obs_label(&key.1), t0, [0; 4]);
        cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_count_and_missing_artifact_errors() {
        // Counters are global and other tests may bump them concurrently,
        // so assert only monotonic deltas we caused ourselves.
        let before = stats();
        assert!(
            grad_engine(&BackendSpec::pjrt(), "artifacts", "no_such_model_xyz").is_err()
        );
        assert!(
            grad_engine(&BackendSpec::native(), "artifacts", "no_such_model_xyz").is_err()
        );
        hits().add(2);
        misses().inc();
        let after = stats();
        assert!(after.hits >= before.hits + 2);
        assert!(after.misses >= before.misses + 1);
        assert_eq!(after.compiles(), after.misses);
    }

    #[test]
    fn native_engines_cache_per_thread() {
        let spec = BackendSpec::native();
        let a = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
        let b = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
        assert!(Rc::ptr_eq(&a, &b), "second lookup must be a cache hit");
        let c = train_compiled(&spec, "artifacts", "mlp_tiny", "adam").unwrap();
        let d = train_compiled(&spec, "artifacts", "mlp_tiny", "adam").unwrap();
        assert!(Rc::ptr_eq(&c, &d));
    }
}
