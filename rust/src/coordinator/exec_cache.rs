//! Compile-once executable cache (DESIGN.md §9, §11).
//!
//! Compiled executables are thread-confined (the PJRT wrapper types are
//! not `Send`), so each worker thread owns its backends
//! ([`thread_backend`]) plus a thread-local cache of compiled executables
//! keyed by `(backend, device, artifact name, manifest hash)`. A 50-point
//! LR sweep on a 4-worker pool therefore compiles each distinct artifact
//! at most 4 times (once per worker that touches it) instead of 50 — and
//! because the sweep scheduler shards jobs by the same backend+artifact
//! key (`SweepScheduler::shard_key`), usually exactly once.
//!
//! Keying on the manifest hash, not just the name, means re-running
//! `make artifacts` mid-process can never serve a stale executable: a
//! re-lowered artifact has a new manifest digest and misses the cache.
//! Keying on `(backend, device)` means a mixed pool — PJRT artifacts next
//! to native interpreter runs, or (later) CPU next to GPU clients — never
//! cross-serves an executable compiled for a different engine.
//!
//! The global [`stats`] counters aggregate hits/misses across all worker
//! threads so tests and benches can assert the compile-once property.
//! They live in the observability metrics registry (`exec_cache.hits` /
//! `exec_cache.misses` — DESIGN.md §15), so the same numbers reach the
//! end-of-sweep summary line, `RunSummary.metrics`, and `slimadam obs
//! report` without any ad-hoc printing here. Cache lookups additionally
//! emit `cache_hit` / `cache_miss` / `compile` spans when tracing is live.
//!
//! **Bounded for daemon lifetimes.** A one-shot CLI sweep dies with its
//! caches, but the `slimadam serve` daemon keeps worker threads (and so
//! these thread-locals) alive indefinitely — an unbounded map would leak
//! one compiled executable per distinct `(backend, device, artifact,
//! manifest)` forever. Each per-thread executable cache is therefore an
//! LRU capped at [`SLIMADAM_EXEC_CACHE_CAP`](thread_cache_cap) entries
//! (default 32); evictions bump the registry's `exec_cache.evictions`
//! counter. The tiny [`thread_backend`] map (a handful of backend/device
//! pairs, not per-artifact) stays uncapped.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::obs::{self, registry, SpanKind};
use crate::runtime::backend::{backend_for, Backend, BackendSpec};
use crate::runtime::engine::{Compiled, GradEngine};

fn hits() -> &'static Arc<registry::Counter> {
    static C: OnceLock<Arc<registry::Counter>> = OnceLock::new();
    C.get_or_init(|| registry::counter("exec_cache.hits"))
}

fn misses() -> &'static Arc<registry::Counter> {
    static C: OnceLock<Arc<registry::Counter>> = OnceLock::new();
    C.get_or_init(|| registry::counter("exec_cache.misses"))
}

fn evictions() -> &'static Arc<registry::Counter> {
    static C: OnceLock<Arc<registry::Counter>> = OnceLock::new();
    C.get_or_init(|| registry::counter("exec_cache.evictions"))
}

/// Snapshot of the global cache counters (all worker threads combined).
/// Every miss is exactly one backend compilation; every eviction is one
/// executable dropped by the per-thread LRU cap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Compilations performed (alias for `misses`, named for intent).
    pub fn compiles(&self) -> u64 {
        self.misses
    }
}

/// Read the global hit/miss/eviction counters.
pub fn stats() -> CacheStats {
    CacheStats {
        hits: hits().get(),
        misses: misses().get(),
        evictions: evictions().get(),
    }
}

/// Zero the global counters (tests and benches bracket sweeps with this).
pub fn reset_stats() {
    hits().reset();
    misses().reset();
    evictions().reset();
}

/// Record a cache hit (instant span + counter).
fn note_hit(name: &str) {
    hits().inc();
    if obs::enabled() {
        obs::emit_instant(SpanKind::CacheHit, obs::intern(name), [0; 4]);
    }
}

/// Record a cache miss; returns a [`obs::clock`] mark so the caller can
/// close the `compile` span over the actual compilation.
fn note_miss(name: &str) -> u64 {
    misses().inc();
    if obs::enabled() {
        obs::emit_instant(SpanKind::CacheMiss, obs::intern(name), [0; 4]);
    }
    obs::clock()
}

/// Intern a span label only when tracing is live.
fn obs_label(name: &str) -> u32 {
    if obs::enabled() {
        obs::intern(name)
    } else {
        obs::NO_LABEL
    }
}

/// Cache key: execution identity (backend kind + device) plus artifact
/// identity (name + manifest digest).
type Key = (BackendSpec, String, u64);

/// LRU slot: last-touch tick + the cached executable.
type Slot<T> = (u64, Rc<T>);

/// Default per-thread executable-cache capacity (entries per map).
const DEFAULT_CAP: usize = 32;

thread_local! {
    static BACKENDS: RefCell<HashMap<BackendSpec, Rc<dyn Backend>>> =
        RefCell::new(HashMap::new());
    static GRAD: RefCell<HashMap<Key, Slot<GradEngine>>> =
        RefCell::new(HashMap::new());
    static TRAIN: RefCell<HashMap<Key, Slot<Compiled>>> =
        RefCell::new(HashMap::new());
    /// Monotonic per-thread touch clock for LRU ordering.
    static TICK: Cell<u64> = Cell::new(0);
    /// Per-thread cap override (tests); `None` = env/default.
    static CAP_OVERRIDE: Cell<Option<usize>> = Cell::new(None);
}

/// The executable-cache capacity for this thread:
/// [`set_thread_cache_cap`] override, else `SLIMADAM_EXEC_CACHE_CAP`
/// (parsed once per process), else [`DEFAULT_CAP`].
pub fn thread_cache_cap() -> usize {
    if let Some(n) = CAP_OVERRIDE.with(|c| c.get()) {
        return n;
    }
    static ENV_CAP: OnceLock<usize> = OnceLock::new();
    *ENV_CAP.get_or_init(|| {
        std::env::var("SLIMADAM_EXEC_CACHE_CAP")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(DEFAULT_CAP)
    })
}

/// Override the LRU capacity for the calling thread (tests exercise
/// eviction without polluting process-wide env state).
pub fn set_thread_cache_cap(n: usize) {
    CAP_OVERRIDE.with(|c| c.set(Some(n.max(1))));
}

fn next_tick() -> u64 {
    TICK.with(|t| {
        let v = t.get() + 1;
        t.set(v);
        v
    })
}

/// Look up `key`, refreshing its LRU tick on a hit.
fn lru_get<T>(cache: &RefCell<HashMap<Key, Slot<T>>>, key: &Key) -> Option<Rc<T>> {
    let mut map = cache.borrow_mut();
    let slot = map.get_mut(key)?;
    slot.0 = next_tick();
    Some(slot.1.clone())
}

/// Insert `value`, evicting least-recently-touched entries past the cap.
fn lru_insert<T>(cache: &RefCell<HashMap<Key, Slot<T>>>, key: Key, value: Rc<T>) {
    let mut map = cache.borrow_mut();
    let cap = thread_cache_cap();
    while map.len() >= cap {
        let oldest = map
            .iter()
            .min_by_key(|(_, (tick, _))| *tick)
            .map(|(k, _)| k.clone());
        let Some(oldest) = oldest else { break };
        map.remove(&oldest);
        evictions().inc();
    }
    map.insert(key, (next_tick(), value));
}

/// This worker thread's backend for `spec`, created on first use. One
/// backend instance per worker is the threading contract here: the PJRT
/// wrapper types are not `Send`, and a CPU client is cheap; the native
/// interpreter is stateless.
pub fn thread_backend(spec: &BackendSpec) -> Result<Rc<dyn Backend>> {
    BACKENDS.with(|slot| {
        if let Some(backend) = slot.borrow().get(spec) {
            return Ok(backend.clone());
        }
        let backend = backend_for(spec)?;
        slot.borrow_mut().insert(*spec, backend.clone());
        Ok(backend)
    })
}

/// Cached split engine for `<model>.grad` on the given backend: compiled
/// at most once per worker thread per `(backend, device, manifest)`.
pub fn grad_engine(spec: &BackendSpec, dir: &str, model: &str) -> Result<Rc<GradEngine>> {
    let name = format!("{model}.grad");
    let backend = thread_backend(spec)?;
    let art = backend.load_artifact(dir.as_ref(), &name)?;
    let key = (*spec, name, art.manifest_hash);
    GRAD.with(|cache| {
        if let Some(engine) = lru_get(cache, &key) {
            note_hit(&key.1);
            return Ok(engine);
        }
        let t0 = note_miss(&key.1);
        let engine = Rc::new(GradEngine::from_artifact(&art, backend.as_ref())?);
        obs::emit_since(SpanKind::Compile, obs_label(&key.1), t0, [0; 4]);
        lru_insert(cache, key, engine.clone());
        Ok(engine)
    })
}

/// Cached compiled fused train-step executable `<model>.train.<ruleset>`.
/// The caller wraps it in a fresh `TrainEngine` per run (state is per-run;
/// the compilation is what's expensive and shareable).
pub fn train_compiled(
    spec: &BackendSpec,
    dir: &str,
    model: &str,
    ruleset: &str,
) -> Result<Rc<Compiled>> {
    let name = format!("{model}.train.{ruleset}");
    let backend = thread_backend(spec)?;
    let art = backend.load_artifact(dir.as_ref(), &name)?;
    anyhow::ensure!(
        art.manifest.kind == "train_step",
        "artifact {} is not a train_step",
        name
    );
    let key = (*spec, name, art.manifest_hash);
    TRAIN.with(|cache| {
        if let Some(compiled) = lru_get(cache, &key) {
            note_hit(&key.1);
            return Ok(compiled);
        }
        let t0 = note_miss(&key.1);
        let compiled = Rc::new(art.compile(backend.as_ref())?);
        obs::emit_since(SpanKind::Compile, obs_label(&key.1), t0, [0; 4]);
        lru_insert(cache, key, compiled.clone());
        Ok(compiled)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_count_and_missing_artifact_errors() {
        // Counters are global and other tests may bump them concurrently,
        // so assert only monotonic deltas we caused ourselves.
        let before = stats();
        assert!(
            grad_engine(&BackendSpec::pjrt(), "artifacts", "no_such_model_xyz").is_err()
        );
        assert!(
            grad_engine(&BackendSpec::native(), "artifacts", "no_such_model_xyz").is_err()
        );
        hits().add(2);
        misses().inc();
        let after = stats();
        assert!(after.hits >= before.hits + 2);
        assert!(after.misses >= before.misses + 1);
        assert_eq!(after.compiles(), after.misses);
    }

    #[test]
    fn native_engines_cache_per_thread() {
        let spec = BackendSpec::native();
        let a = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
        let b = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
        assert!(Rc::ptr_eq(&a, &b), "second lookup must be a cache hit");
        let c = train_compiled(&spec, "artifacts", "mlp_tiny", "adam").unwrap();
        let d = train_compiled(&spec, "artifacts", "mlp_tiny", "adam").unwrap();
        assert!(Rc::ptr_eq(&c, &d));
    }

    #[test]
    fn lru_cap_evicts_least_recently_used() {
        // A dedicated thread: the caches, tick clock, and cap override
        // are all thread-local, so this cannot disturb other tests.
        std::thread::spawn(|| {
            set_thread_cache_cap(2);
            assert_eq!(thread_cache_cap(), 2);
            let spec = BackendSpec::native();
            let evicted_before = stats().evictions;
            let a1 = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
            grad_engine(&spec, "artifacts", "gpt_micro").unwrap();
            // touch mlp_tiny so gpt_micro is the LRU entry…
            let a2 = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
            assert!(Rc::ptr_eq(&a1, &a2));
            // …then a third distinct artifact must evict gpt_micro
            grad_engine(&spec, "artifacts", "conv_mini").unwrap();
            assert!(
                stats().evictions >= evicted_before + 1,
                "insert past the cap must evict"
            );
            // the touched entry survived; the evicted one recompiles
            let a3 = grad_engine(&spec, "artifacts", "mlp_tiny").unwrap();
            assert!(Rc::ptr_eq(&a1, &a3), "recently-used entry must survive");
            let miss_before = stats().misses;
            grad_engine(&spec, "artifacts", "gpt_micro").unwrap();
            assert!(
                stats().misses >= miss_before + 1,
                "evicted entry must recompile on next use"
            );
        })
        .join()
        .unwrap();
    }
}
