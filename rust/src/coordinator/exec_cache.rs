//! Compile-once executable cache (DESIGN.md §9).
//!
//! PJRT wrapper types are not `Send`, so compiled executables cannot be
//! shared across sweep workers. Instead each worker thread owns exactly
//! one PJRT CPU client ([`thread_client`]) plus a thread-local cache of
//! compiled executables keyed by `(artifact name, manifest hash)`. A
//! 50-point LR sweep on a 4-worker pool therefore compiles each distinct
//! artifact at most 4 times (once per worker that touches it) instead of
//! 50 — and because the sweep scheduler shards jobs by artifact
//! (`SweepScheduler::artifact_key`), usually exactly once.
//!
//! Keying on the manifest hash, not just the name, means re-running
//! `make artifacts` mid-process can never serve a stale executable: a
//! re-lowered artifact has a new manifest digest and misses the cache.
//!
//! The global [`stats`] counters aggregate hits/misses across all worker
//! threads so tests and benches can assert the compile-once property.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;
use xla::PjRtClient;

use crate::runtime::engine::{cpu_client, Artifact, Compiled, GradEngine};

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the global cache counters (all worker threads combined).
/// Every miss is exactly one PJRT compilation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Compilations performed (alias for `misses`, named for intent).
    pub fn compiles(&self) -> u64 {
        self.misses
    }
}

/// Read the global hit/miss counters.
pub fn stats() -> CacheStats {
    CacheStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
    }
}

/// Zero the global counters (tests and benches bracket sweeps with this).
pub fn reset_stats() {
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}

thread_local! {
    static CLIENT: RefCell<Option<Rc<PjRtClient>>> = RefCell::new(None);
    static GRAD: RefCell<HashMap<(String, u64), Rc<GradEngine>>> =
        RefCell::new(HashMap::new());
    static TRAIN: RefCell<HashMap<(String, u64), Rc<Compiled>>> =
        RefCell::new(HashMap::new());
}

/// This worker thread's PJRT CPU client, created on first use. One client
/// per worker is the PJRT threading contract here: the wrapper types are
/// not `Send`, and a CPU client is cheap.
pub fn thread_client() -> Result<Rc<PjRtClient>> {
    CLIENT.with(|slot| {
        if let Some(client) = slot.borrow().as_ref() {
            return Ok(client.clone());
        }
        let client = Rc::new(cpu_client()?);
        *slot.borrow_mut() = Some(client.clone());
        Ok(client)
    })
}

/// Cached split engine for `<model>.grad`: compiled at most once per
/// worker thread per manifest revision.
pub fn grad_engine(dir: &str, model: &str) -> Result<Rc<GradEngine>> {
    let name = format!("{model}.grad");
    let art = Artifact::load(dir, &name)?;
    let key = (name, art.manifest_hash);
    GRAD.with(|cache| {
        if let Some(engine) = cache.borrow().get(&key) {
            HITS.fetch_add(1, Ordering::Relaxed);
            return Ok(engine.clone());
        }
        MISSES.fetch_add(1, Ordering::Relaxed);
        let client = thread_client()?;
        let engine = Rc::new(GradEngine::from_artifact(&art, &client)?);
        cache.borrow_mut().insert(key, engine.clone());
        Ok(engine)
    })
}

/// Cached compiled fused train-step executable `<model>.train.<ruleset>`.
/// The caller wraps it in a fresh `TrainEngine` per run (state is per-run;
/// the compilation is what's expensive and shareable).
pub fn train_compiled(dir: &str, model: &str, ruleset: &str) -> Result<Rc<Compiled>> {
    let name = format!("{model}.train.{ruleset}");
    let art = Artifact::load(dir, &name)?;
    anyhow::ensure!(
        art.manifest.kind == "train_step",
        "artifact {} is not a train_step",
        name
    );
    let key = (name, art.manifest_hash);
    TRAIN.with(|cache| {
        if let Some(compiled) = cache.borrow().get(&key) {
            HITS.fetch_add(1, Ordering::Relaxed);
            return Ok(compiled.clone());
        }
        MISSES.fetch_add(1, Ordering::Relaxed);
        let client = thread_client()?;
        let compiled = Rc::new(art.compile(&client)?);
        cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_count_and_missing_artifact_errors() {
        // Counters are global and other tests may bump them concurrently,
        // so assert only monotonic deltas we caused ourselves.
        let before = stats();
        assert!(grad_engine("artifacts", "no_such_model_xyz").is_err());
        HITS.fetch_add(2, Ordering::Relaxed);
        MISSES.fetch_add(1, Ordering::Relaxed);
        let after = stats();
        assert!(after.hits >= before.hits + 2);
        assert!(after.misses >= before.misses + 1);
        assert_eq!(after.compiles(), after.misses);
    }
}
