//! Batch planner + batched group execution (DESIGN.md §12).
//!
//! The compile-once cache (§9) and the backend trait layer (§11) made
//! *compilation* cheap; what remains on the sweep hot path is per-job
//! dispatch. This module stacks same-artifact jobs into one backend call:
//!
//! * [`plan`] groups a worker queue's job indices by **feasibility key**
//!   ([`group_key`]): the `(backend, device, artifact, manifest hash)`
//!   executable identity the job compiles under, plus the schedule shape
//!   (step count, warmup, accumulation, eval setup) the lockstep loop
//!   needs to share. Groups never exceed the requested batch size, never
//!   mix shard keys, and are a deterministic partition of the input
//!   (property-tested in `rust/tests/properties.rs`).
//! * [`run_group`] executes one planned group end to end: per-job data
//!   streams, optimizer/engine state and schedules, stepped in lockstep
//!   through `Executable::run_batch`. Per-job results are bit-identical
//!   to [`run_config`] runs of the same configs — the differential suite
//!   in `rust/tests/batched_agreement.rs` is the contract's proof.
//!
//! Configs that record SNR probes are planned as singleton groups and go
//! through the sequential [`run_config`] path, which owns probing.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::optim::{memory, presets, Optimizer};
use crate::runtime::backend::BackendKind;
use crate::runtime::engine::{Artifact, TrainEngine};
use crate::tensor::Tensor;
use crate::train::{train_fused_batch, train_split_batch, Schedule, SplitJob};

use super::{
    exec_cache, make_data, run_config, synthetic_runs_enabled, EngineKind, RunSummary,
    SweepScheduler, TrainConfig,
};

/// Feasibility key: two jobs may share one batched dispatch group iff
/// their keys match. Extends the scheduler's shard key (backend, device,
/// artifact) with the schedule shape the lockstep loop must share; the
/// artifact's manifest hash is appended by [`plan`] (it needs an artifact
/// lookup, memoized per distinct artifact).
pub fn group_key(cfg: &TrainConfig) -> String {
    let mut key = format!(
        "{}|s{}w{}a{}e{}",
        SweepScheduler::shard_key(cfg),
        cfg.steps,
        cfg.warmup,
        cfg.accum,
        cfg.eval_batches
    );
    if cfg.probe.is_some() {
        // probed configs never batch (run_config owns SNR probing)
        key.push_str("|probe");
    }
    if let Some(p) = &cfg.adaptive {
        // adaptive configs never batch either (mid-run V migrations break
        // the shared-shape contract of lane stacking); the policy still
        // lands in the key so a mixed group is rejected loudly, not
        // silently merged
        key.push_str("|adaptive:");
        key.push_str(&p.key());
    }
    key
}

/// Best-effort manifest hash for a config's artifact — the same digest
/// that keys the executable cache, so a re-lowered artifact can never be
/// grouped with jobs compiled against the old manifest. Missing artifacts
/// hash to 0 (the jobs will fail identically at execution either way).
fn artifact_hash(cfg: &TrainConfig, memo: &mut HashMap<String, u64>) -> u64 {
    let name = SweepScheduler::artifact_key(cfg);
    let memo_key = format!("{}|{name}", cfg.backend.key());
    if let Some(&h) = memo.get(&memo_key) {
        return h;
    }
    let h = match cfg.backend.kind {
        BackendKind::Native => crate::runtime::backend::native::artifact(&name)
            .map(|a| a.manifest_hash)
            .unwrap_or(0),
        BackendKind::Pjrt => Artifact::load("artifacts", &name)
            .map(|a| a.manifest_hash)
            .unwrap_or(0),
    };
    memo.insert(memo_key, h);
    h
}

/// Partition `indices` (into `configs`) into dispatch groups: each group
/// shares one feasibility key, holds at most `max_batch` jobs, and keeps
/// first-seen order — so planning is deterministic and grouping never
/// reorders, reseeds or rewrites a job's config.
pub fn plan(configs: &[TrainConfig], indices: &[usize], max_batch: usize) -> Vec<Vec<usize>> {
    let max = max_batch.max(1);
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut open: HashMap<String, usize> = HashMap::new();
    let mut memo: HashMap<String, u64> = HashMap::new();
    for &i in indices {
        let cfg = &configs[i];
        if max == 1 || cfg.probe.is_some() || cfg.adaptive.is_some() {
            groups.push(vec![i]);
            continue;
        }
        let key = format!("{}|m{:016x}", group_key(cfg), artifact_hash(cfg, &mut memo));
        match open.get(&key) {
            Some(&gi) if groups[gi].len() < max => groups[gi].push(i),
            _ => {
                open.insert(key, groups.len());
                groups.push(vec![i]);
            }
        }
    }
    // planner volume feeds `obs report` (occupancy is observed per group
    // by the scheduler, which also emits the plan_group spans)
    crate::obs::registry::counter("batch.groups_planned").add(groups.len() as u64);
    crate::obs::registry::counter("batch.jobs_planned").add(indices.len() as u64);
    groups
}

/// Execute one planned group. Singleton groups (and synthetic-run mode)
/// take the sequential [`run_config`] path; larger groups run the
/// lockstep batched drivers. Summaries return in group order. Errors
/// carry the failing job's label (sequential path) or the whole group's
/// labels (batched paths, where the jobs fail or succeed together).
pub fn run_group(configs: &[TrainConfig], idxs: &[usize]) -> Result<Vec<RunSummary>> {
    if idxs.len() <= 1 || synthetic_runs_enabled() {
        return idxs
            .iter()
            .map(|&i| {
                run_config(&configs[i])
                    .map_err(|e| anyhow!("{}: {e}", configs[i].label()))
            })
            .collect();
    }
    let first = &configs[idxs[0]];
    for &i in idxs {
        anyhow::ensure!(
            group_key(&configs[i]) == group_key(first),
            "batch group mixes incompatible configs: {} vs {}",
            configs[i].label(),
            first.label()
        );
    }
    anyhow::ensure!(
        first.probe.is_none(),
        "batched groups cannot record SNR probes (the planner routes \
         probed configs through run_config)"
    );
    anyhow::ensure!(
        first.adaptive.is_none(),
        "batched groups cannot run adaptive configs (the planner routes \
         them through run_config as singletons)"
    );
    let result = match &first.engine {
        EngineKind::Split => run_split_group(configs, idxs),
        EngineKind::Fused(ruleset) => run_fused_group(configs, idxs, ruleset),
    };
    result.map_err(|e| {
        let labels: Vec<String> = idxs.iter().map(|&i| configs[i].label()).collect();
        anyhow!("batched group [{}]: {e}", labels.join(", "))
    })
}

/// Initial parameters for a split-engine config: the warm-start tensors
/// when present, else the config's init scheme drawn from
/// `seed.wrapping_add(17)`. The single implementation both
/// [`run_config`]'s split arm and the batched drivers use — sharing it
/// is what keeps batched and sequential initialization identical by
/// construction.
pub fn init_params(
    man: &crate::runtime::Manifest,
    cfg: &TrainConfig,
) -> Vec<Tensor> {
    if let Some(ws) = &cfg.warm_start {
        return ws.as_ref().clone();
    }
    let mut rng = crate::rng::Rng::new(cfg.seed.wrapping_add(17));
    man.params
        .iter()
        .map(|p| {
            let init = if cfg.init == "default" {
                &p.init_default
            } else {
                &p.init_mitchell
            };
            init.materialize(&p.shape, &mut rng)
        })
        .collect()
}

fn run_split_group(configs: &[TrainConfig], idxs: &[usize]) -> Result<Vec<RunSummary>> {
    let first = &configs[idxs[0]];
    let engine = exec_cache::grad_engine(&first.backend, "artifacts", &first.model)?;
    let man = engine.manifest().clone();

    let mut opts: Vec<Box<dyn Optimizer>> = Vec::with_capacity(idxs.len());
    for &i in idxs {
        let cfg = &configs[i];
        let opt = if let Some(rules) = &cfg.ruleset {
            Box::new(presets::build_slimadam(&man, rules, cfg.hypers)) as Box<dyn Optimizer>
        } else {
            presets::build(&cfg.optimizer, &man, cfg.hypers)?
        };
        opts.push(opt);
    }

    let results = {
        let mut jobs: Vec<SplitJob<'_>> = Vec::with_capacity(idxs.len());
        for (opt, &i) in opts.iter_mut().zip(idxs) {
            let cfg = &configs[i];
            jobs.push(SplitJob {
                opt: opt.as_mut(),
                params: init_params(&man, cfg),
                data: make_data(&man, &cfg.data, cfg.seed)?,
                schedule: Schedule::new(cfg.lr, cfg.warmup, cfg.steps),
            });
        }
        train_split_batch(
            &engine,
            &mut jobs,
            first.steps,
            first.accum,
            first.eval_batches,
        )?
    };

    let mut out = Vec::with_capacity(idxs.len());
    for ((&i, opt), result) in idxs.iter().zip(&opts).zip(results) {
        let cfg = &configs[i];
        let steps_per_s = result.losses.len() as f64 / result.wallclock_s.max(1e-9);
        out.push(RunSummary {
            label: cfg.label(),
            model: cfg.model.clone(),
            optimizer: opt.name().to_string(),
            lr: cfg.lr,
            memory: Some(memory::report(opt.as_ref(), man.total_param_elems())),
            result,
            snr: None,
            steps_per_s,
            stored_fingerprint: None,
            metrics: super::obs_metrics(),
            adaptive: None,
        });
    }
    Ok(out)
}

fn run_fused_group(
    configs: &[TrainConfig],
    idxs: &[usize],
    ruleset: &str,
) -> Result<Vec<RunSummary>> {
    let first = &configs[idxs[0]];
    let compiled = exec_cache::train_compiled(&first.backend, "artifacts", &first.model, ruleset)?;
    let man = compiled.manifest.clone();

    let mut engines = Vec::with_capacity(idxs.len());
    let mut datas = Vec::with_capacity(idxs.len());
    let mut schedules = Vec::with_capacity(idxs.len());
    for &i in idxs {
        let cfg = &configs[i];
        let mut engine =
            TrainEngine::with_compiled(compiled.clone(), &cfg.init, cfg.seed.wrapping_add(17))?;
        if let Some(ws) = &cfg.warm_start {
            engine.load_params(ws)?;
        }
        engines.push(engine);
        datas.push(make_data(&man, &cfg.data, cfg.seed)?);
        schedules.push(Schedule::new(cfg.lr, cfg.warmup, cfg.steps));
    }
    let results = train_fused_batch(&mut engines, &mut datas, &schedules, first.steps)?;

    let mut out = Vec::with_capacity(idxs.len());
    for (&i, result) in idxs.iter().zip(results) {
        let cfg = &configs[i];
        let steps_per_s = result.losses.len() as f64 / result.wallclock_s.max(1e-9);
        out.push(RunSummary {
            label: cfg.label(),
            model: cfg.model.clone(),
            optimizer: format!("fused:{ruleset}"),
            lr: cfg.lr,
            result,
            snr: None,
            memory: memory::report_manifest(&man),
            steps_per_s,
            stored_fingerprint: None,
            metrics: super::obs_metrics(),
            adaptive: None,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::BackendSpec;
    use crate::snr::ProbeSchedule;

    fn native_cfg(opt: &str, lr: f64) -> TrainConfig {
        let mut cfg = TrainConfig::lm("mlp_tiny", opt, lr, 10);
        cfg.backend = BackendSpec::native();
        cfg
    }

    #[test]
    fn plan_groups_same_key_up_to_max() {
        let configs: Vec<TrainConfig> =
            (0..6).map(|i| native_cfg("adam", 1e-3 * (i + 1) as f64)).collect();
        let indices: Vec<usize> = (0..6).collect();
        let groups = plan(&configs, &indices, 4);
        assert_eq!(groups, vec![vec![0, 1, 2, 3], vec![4, 5]]);
        // max 1 → all singletons
        let singles = plan(&configs, &indices, 1);
        assert_eq!(singles.len(), 6);
        assert!(singles.iter().all(|g| g.len() == 1));
    }

    #[test]
    fn plan_never_mixes_shard_or_schedule_keys() {
        let mut configs = vec![native_cfg("adam", 1e-3), native_cfg("adam", 2e-3)];
        let mut other_steps = native_cfg("adam", 1e-3);
        other_steps.steps = 99;
        configs.push(other_steps);
        let mut pjrt = TrainConfig::lm("mlp_tiny", "adam", 1e-3, 10);
        pjrt.backend = BackendSpec::pjrt();
        configs.push(pjrt);
        let indices: Vec<usize> = (0..configs.len()).collect();
        let groups = plan(&configs, &indices, 8);
        assert_eq!(groups, vec![vec![0, 1], vec![2], vec![3]]);
        for g in &groups {
            let k0 = group_key(&configs[g[0]]);
            assert!(g.iter().all(|&i| group_key(&configs[i]) == k0));
        }
    }

    #[test]
    fn plan_isolates_probed_configs() {
        let mut probed = native_cfg("adam", 1e-3);
        probed.probe = Some(ProbeSchedule::default());
        let configs = vec![native_cfg("adam", 1e-3), probed, native_cfg("adam", 2e-3)];
        // the probed config is always its own group; the compatible
        // unprobed jobs around it still share one
        let groups = plan(&configs, &[0, 1, 2], 8);
        assert_eq!(groups, vec![vec![0, 2], vec![1]]);
        let groups2 = plan(&configs, &[1, 0, 2], 8);
        assert_eq!(groups2, vec![vec![1], vec![0, 2]]);
    }

    #[test]
    fn group_key_separates_engines_and_eval_setup() {
        let base = native_cfg("adam", 1e-3);
        let mut fused = base.clone();
        fused.engine = EngineKind::Fused("slimadam".into());
        assert_ne!(group_key(&base), group_key(&fused));
        let mut eval = base.clone();
        eval.eval_batches = 99;
        assert_ne!(group_key(&base), group_key(&eval));
        let mut acc = base.clone();
        acc.accum = 4;
        assert_ne!(group_key(&base), group_key(&acc));
        // lr and seed are per-job state, not feasibility
        let mut lr = base.clone();
        lr.lr = 9e-9;
        lr.seed = 123;
        assert_eq!(group_key(&base), group_key(&lr));
    }

    #[test]
    fn run_group_rejects_mixed_groups() {
        let a = native_cfg("adam", 1e-3);
        let mut b = native_cfg("adam", 1e-3);
        b.steps = 99;
        let configs = vec![a, b];
        let err = run_group(&configs, &[0, 1]).unwrap_err();
        assert!(format!("{err}").contains("mixes"), "{err}");
    }
}
