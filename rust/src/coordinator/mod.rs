//! Experiment coordinator: the single entry point that turns a declarative
//! [`TrainConfig`] into a finished run, and fans whole config grids out
//! across a work-stealing worker pool.
//!
//! Layering (DESIGN.md §9):
//!
//! * [`run_config`] — one config, end to end, on the calling thread. All
//!   randomness derives from `TrainConfig::seed`, so a run is a pure
//!   function of its config.
//! * [`exec_cache`] — per-worker-thread compile-once executable cache
//!   keyed by `(backend, device, artifact name, manifest hash)`
//!   (DESIGN.md §11). Each worker owns its own backend instances (the
//!   `xla` wrapper types are not `Send`).
//! * [`batch`] — batched in-worker dispatch (DESIGN.md §12): plans
//!   same-artifact jobs into dispatch groups and steps each group in
//!   lockstep through one `Executable::run_batch` call per training
//!   step, bit-identically to sequential execution.
//! * [`scheduler`] / [`SweepScheduler`] — shards a config grid across
//!   workers by `(backend, device, artifact)`, steals work across
//!   shards (whole groups when batching), streams per-job JSONL rows as
//!   jobs finish, and guarantees parallel == serial results job-for-job.
//!
//! Everything the figure/table reproductions need funnels through
//! [`run_config`] / [`run_grid`], so sweep results are directly comparable.

pub mod batch;
pub mod exec_cache;
pub mod scheduler;

pub use scheduler::SweepScheduler;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::data::corpus::TokenCorpus;
use crate::data::images::SynthImages;
use crate::data::markov::MarkovLm;
use crate::data::DataSource;
use crate::optim::memory::MemoryReport;
use crate::optim::{presets, Hypers};
use crate::rules::adaptive::{AdaptivePolicy, AdaptiveReport};
use crate::rules::RuleSet;
use crate::runtime::backend::{BackendKind, BackendSpec};
use crate::runtime::engine::TrainEngine;
use crate::snr::{ProbeSchedule, SnrSummary};
use crate::tensor::Tensor;
use crate::train::{train_fused, train_fused_adaptive, train_split, RunResult, Schedule};

/// Which execution engine to use.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineKind {
    /// HLO grad_step + Rust optimizer (any optimizer name / ruleset).
    Split,
    /// Single-dispatch fused train_step artifact (`<model>.train.<ruleset>`).
    Fused(String),
}

/// Data workload specification.
#[derive(Debug, Clone)]
pub enum DataSpec {
    /// Zipf+Markov synthetic LM (DESIGN.md §3).
    Markov { alpha: f64, coherence: f64, seed: u64 },
    /// Distribution-shifted Markov for fine-tuning runs.
    MarkovShifted { alpha: f64, coherence: f64, seed: u64 },
    /// Real repo-source corpus, BPE'd at the model's vocab size.
    Corpus,
    /// Synthetic class-conditional images.
    Images { noise: f64, seed: u64 },
}

impl DataSpec {
    /// Paper-default Zipf+Markov LM stream (DESIGN.md §3) — the single
    /// source of the LM data constants [`TrainConfig::lm`] uses.
    pub fn default_markov() -> DataSpec {
        DataSpec::Markov {
            alpha: 1.07,
            coherence: 0.5,
            seed: 1234,
        }
    }

    /// Paper-default synthetic image stream — the single source of the
    /// vision data constants [`TrainConfig::vision`] uses.
    pub fn default_images() -> DataSpec {
        DataSpec::Images { noise: 0.3, seed: 99 }
    }

    /// The default workload for a manifest's batch layout: f32 image
    /// batches (the vision families) get [`DataSpec::default_images`],
    /// token batches [`DataSpec::default_markov`] — by construction the
    /// same streams [`TrainConfig::vision`] / [`TrainConfig::lm`] train on.
    pub fn default_for(man: &crate::runtime::Manifest) -> DataSpec {
        let vision = man
            .batch
            .first()
            .map(|b| b.dtype == "f32")
            .unwrap_or(false);
        if vision {
            DataSpec::default_images()
        } else {
            DataSpec::default_markov()
        }
    }
}

/// A complete training-run specification.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub optimizer: String,
    /// Explicit SlimAdam rules (overrides the named preset when set).
    pub ruleset: Option<RuleSet>,
    pub engine: EngineKind,
    /// Execution backend + device + compute precision (DESIGN.md §11,
    /// §14). Part of the run's identity: hashed into
    /// `runstore::config_key`, the executable-cache key and the
    /// scheduler shard key. The f32 native mode keys as `native+f32@…`,
    /// so its rows never alias the f64 verify reference; intra-op worker
    /// count is *not* part of identity — kernel results are
    /// worker-invariant by contract.
    pub backend: BackendSpec,
    pub lr: f64,
    pub steps: usize,
    pub warmup: usize,
    pub seed: u64,
    /// "mitchell" | "default" (§4.3)
    pub init: String,
    pub data: DataSpec,
    pub probe: Option<ProbeSchedule>,
    pub hypers: Hypers,
    pub eval_batches: usize,
    pub accum: usize,
    /// Warm-start parameters (fine-tuning): loaded before training.
    pub warm_start: Option<Arc<Vec<Tensor>>>,
    /// Self-tuning rule switching (DESIGN.md §18). Only valid with a
    /// fused engine on the native backend; part of the run's identity
    /// (`runstore::config_key` appends the policy's bit-exact key) and
    /// forces the batch planner to a singleton group.
    pub adaptive: Option<AdaptivePolicy>,
}

impl TrainConfig {
    /// Paper-default LM config on the synthetic corpus.
    pub fn lm(model: &str, optimizer: &str, lr: f64, steps: usize) -> TrainConfig {
        TrainConfig {
            model: model.into(),
            optimizer: optimizer.into(),
            ruleset: None,
            engine: EngineKind::Split,
            backend: BackendSpec::default(),
            lr,
            steps,
            warmup: steps / 5, // paper: 2048 of 10k ≈ 20%
            seed: 0,
            init: "mitchell".into(),
            data: DataSpec::default_markov(),
            probe: None,
            hypers: Hypers::default(),
            eval_batches: 8,
            accum: 1,
            warm_start: None,
            adaptive: None,
        }
    }

    /// Vision config (paper App. B.4 hypers: beta2=0.999, wd=0.01).
    pub fn vision(model: &str, optimizer: &str, lr: f64, steps: usize) -> TrainConfig {
        let mut cfg = TrainConfig::lm(model, optimizer, lr, steps);
        cfg.data = DataSpec::default_images();
        cfg.hypers = Hypers {
            beta2: 0.999,
            weight_decay: 0.01,
            ..Hypers::default()
        };
        cfg
    }

    /// True when a model name belongs to a vision family (ViT / ResNet
    /// artifacts, or the native conv zoo) and should default to the
    /// vision config.
    pub fn is_vision(model: &str) -> bool {
        model.starts_with("vit") || model.starts_with("resnet") || model.starts_with("conv")
    }

    /// Model-name dispatch: vision-family models get [`TrainConfig::vision`],
    /// everything else [`TrainConfig::lm`]. This is the single place the
    /// CLI, benches and differential tests use so a grid over the whole
    /// model zoo builds the right data spec per family.
    pub fn auto(model: &str, optimizer: &str, lr: f64, steps: usize) -> TrainConfig {
        if TrainConfig::is_vision(model) {
            TrainConfig::vision(model, optimizer, lr, steps)
        } else {
            TrainConfig::lm(model, optimizer, lr, steps)
        }
    }

    /// Fine-tuning config (paper App. B.3: beta2=0.999, low LR, shifted
    /// data, warm start supplied by the caller).
    pub fn finetune(model: &str, optimizer: &str, lr: f64, steps: usize) -> TrainConfig {
        let mut cfg = TrainConfig::lm(model, optimizer, lr, steps);
        cfg.data = DataSpec::MarkovShifted {
            alpha: 1.07,
            coherence: 0.5,
            seed: 1234,
        };
        cfg.hypers = Hypers {
            beta2: 0.999,
            ..Hypers::default()
        };
        cfg.warmup = steps / 10;
        cfg
    }

    pub fn label(&self) -> String {
        format!(
            "{}/{}@lr{:.0e}{}{}",
            self.model,
            match &self.engine {
                EngineKind::Split => self.optimizer.clone(),
                EngineKind::Fused(r) => format!("fused:{r}"),
            },
            self.lr,
            if self.init == "default" { "/definit" } else { "" },
            match &self.adaptive {
                Some(p) => format!("+ad[{}]", p.spec()),
                None => String::new(),
            }
        )
    }
}

/// Summary of one finished run (what sweeps and figures consume).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub model: String,
    pub optimizer: String,
    pub lr: f64,
    pub result: RunResult,
    pub snr: Option<SnrSummary>,
    pub memory: Option<MemoryReport>,
    pub steps_per_s: f64,
    /// Set when this summary was restored from the run store instead of
    /// executed: the fingerprint the original run streamed. Restored
    /// summaries carry no per-step losses, so [`RunSummary::fingerprint`]
    /// must use this instead of recomputing.
    pub stored_fingerprint: Option<u64>,
    /// Flight-recorder metric snapshot at run completion (DESIGN.md §15).
    /// Populated only while tracing is live, so untraced rows are
    /// byte-identical to pre-observability output; never part of the
    /// fingerprint.
    pub metrics: Option<crate::json::Value>,
    /// Adaptive-controller report (DESIGN.md §18): the decision log,
    /// memory timeline and final compression state. `Some` only for
    /// adaptive runs; streamed into the run-store row (decisions replay
    /// deterministically on resume) but never part of the fingerprint.
    pub adaptive: Option<AdaptiveReport>,
}

/// Registry snapshot for a completing run — `Some` only when the flight
/// recorder is live (counters are process-global, so the snapshot reads
/// as "metrics as of this row", not a per-run delta).
pub(crate) fn obs_metrics() -> Option<crate::json::Value> {
    if crate::obs::enabled() {
        Some(crate::obs::registry::snapshot())
    } else {
        None
    }
}

impl RunSummary {
    /// The run's metrics digest: the stored fingerprint for a summary
    /// restored from the run store, else computed from the live result.
    pub fn fingerprint(&self) -> u64 {
        self.stored_fingerprint
            .unwrap_or_else(|| self.result.fingerprint())
    }

    /// True when this job was skipped on resume and restored from the
    /// run store rather than executed.
    pub fn restored(&self) -> bool {
        self.stored_fingerprint.is_some()
    }

    pub fn to_json(&self) -> crate::json::Value {
        let mut v = crate::json::Value::obj();
        // Non-finite losses (diverged runs) use the -1.0 sentinel: JSON
        // has no NaN/Inf, and an unserializable loss would otherwise make
        // the streamed row unindexable — forcing resume to re-run exactly
        // the diverged grid points. `runstore::index` maps -1.0 back.
        v.set("label", self.label.clone())
            .set("model", self.model.clone())
            .set("optimizer", self.optimizer.clone())
            .set("lr", self.lr)
            .set("final_train_loss", finite_or(self.result.final_train_loss, -1.0))
            .set("eval_loss", finite_or(self.result.eval_loss, -1.0))
            .set("diverged", self.result.diverged)
            .set("steps", self.result.losses.len())
            .set("steps_per_s", self.steps_per_s)
            .set("wallclock_s", self.result.wallclock_s);
        if let Some(m) = &self.memory {
            v.set("memory", m.to_json());
        }
        if let Some(m) = &self.metrics {
            v.set("metrics", m.clone());
        }
        if let Some(a) = &self.adaptive {
            v.set("adaptive", a.to_json());
        }
        v
    }
}

fn finite_or(x: f64, d: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        d
    }
}

// ---------------------------------------------------------------------------
// Corpus cache: BPE training is expensive; share tokenized corpora across
// jobs (keyed by vocab size).
// ---------------------------------------------------------------------------

static CORPUS_CACHE: OnceLock<Mutex<HashMap<usize, Arc<TokenCorpus>>>> = OnceLock::new();

/// Tokenize the repo corpus once at the largest standard vocabulary.
fn base_corpus_tokens() -> Result<Arc<TokenCorpus>> {
    let cache = CORPUS_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    {
        let guard = cache.lock().unwrap();
        if let Some(c) = guard.get(&usize::MAX) {
            return Ok(c.clone());
        }
    }
    let text = crate::data::corpus::collect_text(".")?;
    let sample = &text[..text.len().min(150_000)];
    let bpe = crate::data::bpe::Bpe::train(sample, 4096);
    let toks: Vec<i32> = bpe.encode(&text).iter().map(|&t| t as i32).collect();
    let corpus = Arc::new(TokenCorpus::from_tokens("repo_base", bpe.vocab_size, toks));
    cache
        .lock()
        .unwrap()
        .insert(usize::MAX, corpus.clone());
    Ok(corpus)
}

/// Repo corpus restricted to `vocab` tokens by frequency-rank truncation:
/// the most frequent `vocab-1` BPE tokens keep their rank as their id and
/// everything rarer maps to the final `<unk>` bucket. Shrinking `vocab`
/// removes exactly the distribution's tail — the §4.1 control variable —
/// while every sweep point shares the same head tokens.
fn corpus_for_vocab(vocab: usize) -> Result<Arc<TokenCorpus>> {
    anyhow::ensure!(vocab >= 2, "vocab too small");
    let cache = CORPUS_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    {
        let guard = cache.lock().unwrap();
        if let Some(c) = guard.get(&vocab) {
            return Ok(c.clone());
        }
    }
    let base = base_corpus_tokens()?;
    // frequency ranks over the base stream
    let mut counts: HashMap<i32, usize> = HashMap::new();
    for &t in &base.tokens {
        *counts.entry(t).or_default() += 1;
    }
    let mut by_freq: Vec<(i32, usize)> = counts.into_iter().collect();
    by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut remap: HashMap<i32, i32> = HashMap::new();
    for (rank, (tok, _)) in by_freq.iter().enumerate() {
        remap.insert(
            *tok,
            if rank < vocab - 1 {
                rank as i32
            } else {
                (vocab - 1) as i32 // <unk> tail bucket
            },
        );
    }
    let toks: Vec<i32> = base.tokens.iter().map(|t| remap[t]).collect();
    let corpus = Arc::new(TokenCorpus::from_tokens(
        format!("repo_v{vocab}"),
        vocab,
        toks,
    ));
    cache.lock().unwrap().insert(vocab, corpus.clone());
    Ok(corpus)
}

/// Build the data source matching a manifest's batch layout.
pub fn make_data(
    man: &crate::runtime::Manifest,
    spec: &DataSpec,
    run_seed: u64,
) -> Result<Box<dyn DataSource>> {
    let b = man.batch[0].shape[0];
    match spec {
        DataSpec::Markov { alpha, coherence, seed } => {
            let t = man.batch[0].shape[1];
            let lm = MarkovLm::new(man.token_bound(), *alpha, *coherence, *seed);
            Ok(Box::new(lm.source(b, t, run_seed ^ 0x5A5A)))
        }
        DataSpec::MarkovShifted { alpha, coherence, seed } => {
            let t = man.batch[0].shape[1];
            let lm = MarkovLm::new(man.token_bound(), *alpha, *coherence, *seed)
                .shifted(*seed);
            Ok(Box::new(lm.source(b, t, run_seed ^ 0x5A5B)))
        }
        DataSpec::Corpus => {
            let t = man.batch[0].shape[1];
            let corpus = corpus_for_vocab(man.token_bound())?;
            Ok(Box::new(ArcCorpusSource::new(corpus, b, t, run_seed)))
        }
        DataSpec::Images { noise, seed } => {
            let img = man.batch[0].shape[1];
            let ch = man.batch[0].shape[3];
            let gen = SynthImages::new(man.token_bound(), img, ch, *noise, *seed);
            Ok(Box::new(gen.source(b, run_seed ^ 0x1111)))
        }
    }
}

/// DataSource over a shared (Arc) corpus.
struct ArcCorpusSource {
    corpus: Arc<TokenCorpus>,
    rng_train: crate::rng::Rng,
    rng_eval: crate::rng::Rng,
    batch: usize,
    ctx: usize,
}

impl ArcCorpusSource {
    fn new(corpus: Arc<TokenCorpus>, batch: usize, ctx: usize, seed: u64) -> Self {
        let mut root = crate::rng::Rng::new(seed ^ 0xC0DE);
        ArcCorpusSource {
            corpus,
            rng_train: root.fork(1),
            rng_eval: root.fork(2),
            batch,
            ctx,
        }
    }

    fn make(&mut self, eval: bool) -> Vec<crate::runtime::engine::BatchData> {
        let (b, t) = (self.batch, self.ctx);
        let need = t + 1;
        let n = self.corpus.tokens.len();
        let split = n * 9 / 10;
        let mut xs = vec![0i32; b * t];
        let mut ys = vec![0i32; b * t];
        for i in 0..b {
            let rng = if eval { &mut self.rng_eval } else { &mut self.rng_train };
            let (lo, hi) = if eval {
                (split, n - need)
            } else {
                (0, split - need)
            };
            let start = lo + rng.usize_below((hi - lo).max(1));
            let seq = &self.corpus.tokens[start..start + need];
            xs[i * t..(i + 1) * t].copy_from_slice(&seq[..t]);
            ys[i * t..(i + 1) * t].copy_from_slice(&seq[1..]);
        }
        vec![
            crate::runtime::engine::BatchData::I32(xs),
            crate::runtime::engine::BatchData::I32(ys),
        ]
    }
}

impl DataSource for ArcCorpusSource {
    fn next_batch(&mut self) -> Vec<crate::runtime::engine::BatchData> {
        self.make(false)
    }

    fn eval_batch(&mut self) -> Vec<crate::runtime::engine::BatchData> {
        self.make(true)
    }

    fn name(&self) -> &str {
        &self.corpus.name
    }
}

// ---------------------------------------------------------------------------
// Run execution
// ---------------------------------------------------------------------------

/// Execute one training config end to end on the calling thread.
///
/// Compiled executables come from [`exec_cache`] (per-worker backends,
/// compile-once per `(backend, device, artifact, manifest hash)`), and
/// every random draw — init, data order, eval batches — derives from
/// `cfg.seed`, so the result is a pure function of the config: the
/// scheduler can run it on any worker, in any order, and produce
/// identical metrics.
pub fn run_config(cfg: &TrainConfig) -> Result<RunSummary> {
    if synthetic_runs_enabled() {
        return Ok(synthetic_run(cfg));
    }
    if let Some(policy) = &cfg.adaptive {
        policy.validate().map_err(|e| anyhow::anyhow!("{}: {e}", cfg.label()))?;
        anyhow::ensure!(
            matches!(cfg.engine, EngineKind::Fused(_)),
            "{}: --adaptive needs a fused engine (the controller migrates \
             fused V state in place)",
            cfg.label()
        );
        anyhow::ensure!(
            cfg.backend.kind == BackendKind::Native,
            "{}: --adaptive is native-only (the native backend infers the \
             effective K mode from stored V lengths; PJRT executables bake \
             fixed shapes)",
            cfg.label()
        );
    }
    let schedule = Schedule::new(cfg.lr, cfg.warmup, cfg.steps);

    match &cfg.engine {
        EngineKind::Split => {
            let engine = exec_cache::grad_engine(&cfg.backend, "artifacts", &cfg.model)?;
            let man = engine.manifest().clone();
            let mut data = make_data(&man, &cfg.data, cfg.seed)?;

            // init params (shared with the batched drivers so sequential
            // and batched initialization can never drift)
            let mut params: Vec<Tensor> = batch::init_params(&man, cfg);

            let mut opt = if let Some(rules) = &cfg.ruleset {
                Box::new(presets::build_slimadam(&man, rules, cfg.hypers))
                    as Box<dyn crate::optim::Optimizer>
            } else {
                presets::build(&cfg.optimizer, &man, cfg.hypers)?
            };

            let result = train_split(
                &engine,
                opt.as_mut(),
                &mut params,
                data.as_mut(),
                &schedule,
                cfg.steps,
                cfg.probe,
                cfg.accum,
                cfg.eval_batches,
            )?;
            let snr = if cfg.probe.is_some() {
                Some(result.probe.summary(&man.params))
            } else {
                None
            };
            let steps_per_s = result.losses.len() as f64 / result.wallclock_s.max(1e-9);
            Ok(RunSummary {
                label: cfg.label(),
                model: cfg.model.clone(),
                optimizer: opt.name().to_string(),
                lr: cfg.lr,
                memory: Some(crate::optim::memory::report(
                    opt.as_ref(),
                    man.total_param_elems(),
                )),
                result,
                snr,
                steps_per_s,
                stored_fingerprint: None,
                metrics: obs_metrics(),
                adaptive: None,
            })
        }
        EngineKind::Fused(ruleset) => {
            let compiled =
                exec_cache::train_compiled(&cfg.backend, "artifacts", &cfg.model, ruleset)?;
            let mut engine =
                TrainEngine::with_compiled(compiled, &cfg.init, cfg.seed.wrapping_add(17))?;
            if let Some(ws) = &cfg.warm_start {
                engine.load_params(ws)?;
            }
            let man = engine.manifest().clone();
            let mut data = make_data(&man, &cfg.data, cfg.seed)?;
            let (result, adaptive) = match &cfg.adaptive {
                Some(policy) => {
                    let (r, rep) = train_fused_adaptive(
                        &mut engine,
                        data.as_mut(),
                        &schedule,
                        cfg.steps,
                        cfg.probe,
                        *policy,
                    )?;
                    (r, Some(rep))
                }
                None => (
                    train_fused(&mut engine, data.as_mut(), &schedule, cfg.steps, cfg.probe)?,
                    None,
                ),
            };
            let snr = if cfg.probe.is_some() {
                Some(result.probe.summary(&man.params))
            } else {
                None
            };
            let steps_per_s = result.losses.len() as f64 / result.wallclock_s.max(1e-9);
            Ok(RunSummary {
                label: cfg.label(),
                model: cfg.model.clone(),
                optimizer: format!("fused:{ruleset}"),
                lr: cfg.lr,
                result,
                snr,
                memory: crate::optim::memory::report_manifest(&man),
                steps_per_s,
                stored_fingerprint: None,
                metrics: obs_metrics(),
                adaptive,
            })
        }
    }
}

// ---------------------------------------------------------------------------
// Synthetic run mode
// ---------------------------------------------------------------------------

/// `SLIMADAM_SYNTH_RUNS=1` replaces artifact execution in [`run_config`]
/// with a deterministic synthetic result — a pure function of the config,
/// like a real run, but needing no artifacts or PJRT. This is the
/// substrate for the kill-and-resume CI smoke job and the resume
/// determinism tests (`rust/tests/runstore_resume.rs`); pair with
/// `SLIMADAM_SYNTH_MS=<n>` to give each job a wall-clock cost.
pub fn synthetic_runs_enabled() -> bool {
    std::env::var("SLIMADAM_SYNTH_RUNS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn synthetic_run(cfg: &TrainConfig) -> RunSummary {
    if let Ok(ms) = std::env::var("SLIMADAM_SYNTH_MS") {
        if let Ok(ms) = ms.parse::<u64>() {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
    let key = crate::rng::stable_hash64(
        format!("synth|{}|{:x}", cfg.label(), cfg.seed).as_bytes(),
    );
    let mut rng = crate::rng::Rng::new(key);
    // Loss curve: exponential decay toward an LR-dependent floor, with
    // divergence above a fixed LR knee — enough structure for U-curve
    // charts and best-LR selection to behave like a real sweep.
    let diverged = cfg.lr > 3e-2;
    let l0 = 6.0 + rng.uniform(0.0, 0.5);
    let floor = 1.2 + (cfg.lr.log10() + 3.0).abs() * 0.4;
    let mut losses = Vec::with_capacity(cfg.steps);
    for t in 1..=cfg.steps {
        let progress = t as f64 / cfg.steps.max(1) as f64;
        let loss = if diverged {
            // explode to non-finite like a real diverged run, so the
            // -1.0 row sentinel and its restore path stay exercised by
            // the artifact-free resume tests and CI smoke
            if progress > 0.75 {
                f64::INFINITY
            } else {
                l0 * (1.0 + 10.0 * progress)
            }
        } else {
            floor + (l0 - floor) * (-4.0 * progress).exp() + rng.uniform(0.0, 0.02)
        };
        losses.push((t, loss as f32));
    }
    let tail = (losses.len() / 10).max(1);
    let final_train_loss = losses.iter().rev().take(tail).map(|&(_, l)| l as f64).sum::<f64>()
        / tail as f64;
    let eval_loss = final_train_loss + rng.uniform(0.01, 0.05);
    RunSummary {
        label: cfg.label(),
        model: cfg.model.clone(),
        optimizer: match &cfg.engine {
            EngineKind::Split => cfg.optimizer.clone(),
            EngineKind::Fused(r) => format!("fused:{r}"),
        },
        lr: cfg.lr,
        result: RunResult {
            losses,
            final_train_loss,
            eval_loss,
            diverged,
            probe: crate::snr::SnrProbe::new(),
            wallclock_s: 0.0,
        },
        snr: None,
        memory: None,
        steps_per_s: 0.0,
        stored_fingerprint: None,
        metrics: None,
        adaptive: None,
    }
}

/// Run a grid of configs on the work-stealing sweep scheduler; order
/// preserved. Shorthand for `SweepScheduler::new(workers).run(configs)` —
/// build a [`SweepScheduler`] directly for streaming or derived seeds.
pub fn run_grid(configs: &[TrainConfig], workers: usize) -> Result<Vec<RunSummary>> {
    SweepScheduler::new(workers).run(configs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/linear2_v64.grad.hlo.txt").exists()
    }

    #[test]
    fn label_formatting() {
        let cfg = TrainConfig::lm("gpt_nano", "adam", 3e-4, 100);
        assert!(cfg.label().contains("gpt_nano/adam@lr3e-4"));
        let mut f = cfg.clone();
        f.engine = EngineKind::Fused("slimadam".into());
        assert!(f.label().contains("fused:slimadam"));
    }

    #[test]
    fn run_config_linear2_trains() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = TrainConfig::lm("linear2_v64", "adam", 3e-3, 30);
        cfg.probe = Some(ProbeSchedule {
            early_every: 5,
            early_until: 30,
            late_every: 10,
        });
        cfg.eval_batches = 2;
        let s = run_config(&cfg).unwrap();
        assert!(!s.result.diverged);
        assert!(s.result.final_train_loss < s.result.losses[0].1 as f64);
        assert!(s.result.eval_loss.is_finite());
        let snr = s.snr.unwrap();
        assert_eq!(snr.per_param.len(), 2);
        assert!(snr.per_param[0].fan_in.is_finite());
        let mem = s.memory.unwrap();
        assert_eq!(mem.v_elems, mem.param_elems); // adam
    }

    #[test]
    fn run_grid_parallel_two_optimizers() {
        if !have_artifacts() {
            return;
        }
        let configs = vec![
            TrainConfig::lm("linear2_v64", "adam", 1e-3, 10),
            TrainConfig::lm("linear2_v64", "slimadam", 1e-3, 10),
        ];
        let out = run_grid(&configs, 2).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].optimizer, "adam");
        assert!(out[1].optimizer.starts_with("slimadam"));
        // SlimAdam must store strictly less V
        let m0 = out[0].memory.as_ref().unwrap();
        let m1 = out[1].memory.as_ref().unwrap();
        assert!(m1.v_elems < m0.v_elems);
    }
}
