//! npy / npz substrate: reads the fixture archives written by numpy on the
//! Python side and writes Rust checkpoints numpy can read back. Implements
//! the npy v1.0 format (the only version numpy emits for plain dtypes) for
//! little-endian f32/f64/i32/i64 arrays, C order.
//!
//! Written in-repo because the `xla` crate's npy writer rejects non-u8
//! literals (its `copy_raw_to::<u8>` type-checks against the literal dtype).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

const MAGIC: &[u8] = b"\x93NUMPY";

/// A loaded array (all numeric dtypes normalized to f32 or i32).
#[derive(Debug, Clone, PartialEq)]
pub enum NpyArray {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl NpyArray {
    pub fn shape(&self) -> &[usize] {
        match self {
            NpyArray::F32 { shape, .. } => shape,
            NpyArray::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<(&[usize], &[f32])> {
        match self {
            NpyArray::F32 { shape, data } => Ok((shape, data)),
            _ => bail!("expected f32 array"),
        }
    }

    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            NpyArray::I32 { shape, data } => Ok((shape, data)),
            _ => bail!("expected i32 array"),
        }
    }
}

// --------------------------------------------------------------------------
// npy core
// --------------------------------------------------------------------------

fn write_npy_bytes(arr: &NpyArray) -> Vec<u8> {
    let (descr, shape, payload): (&str, &[usize], Vec<u8>) = match arr {
        NpyArray::F32 { shape, data } => (
            "<f4",
            shape,
            data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        NpyArray::I32 { shape, data } => (
            "<i4",
            shape,
            data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
    };
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    let unpadded = MAGIC.len() + 2 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.extend(std::iter::repeat(' ').take(pad));
    header.push('\n');

    let mut out = Vec::with_capacity(unpadded + pad + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&[1, 0]);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(&payload);
    out
}

fn parse_npy_bytes(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not an npy file");
    }
    let (major, _minor) = (bytes[6], bytes[7]);
    let (hlen, hstart) = if major == 1 {
        (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        )
    } else {
        (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        )
    };
    let header = std::str::from_utf8(&bytes[hstart..hstart + hlen])
        .context("npy header not utf8")?;
    let descr = dict_field(header, "descr")?;
    let fortran = dict_field(header, "fortran_order")?;
    if fortran.trim() != "False" {
        bail!("fortran order unsupported");
    }
    let shape_src = dict_field(header, "shape")?;
    let shape: Vec<usize> = shape_src
        .trim()
        .trim_start_matches('(')
        .trim_end_matches(')')
        .split(',')
        .filter_map(|s| {
            let s = s.trim();
            if s.is_empty() {
                None
            } else {
                Some(s.parse::<usize>())
            }
        })
        .collect::<std::result::Result<_, _>>()
        .context("parsing shape")?;
    let n: usize = shape.iter().product();
    let data = &bytes[hstart + hlen..];
    let descr = descr.trim().trim_matches('\'').trim_matches('"');
    Ok(match descr {
        "<f4" => {
            anyhow::ensure!(data.len() >= 4 * n, "truncated f4 payload");
            NpyArray::F32 {
                shape,
                data: data[..4 * n]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            }
        }
        "<f8" => NpyArray::F32 {
            shape,
            data: data[..8 * n]
                .chunks_exact(8)
                .map(|c| {
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                        as f32
                })
                .collect(),
        },
        "<i4" => NpyArray::I32 {
            shape,
            data: data[..4 * n]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        },
        "<i8" => NpyArray::I32 {
            shape,
            data: data[..8 * n]
                .chunks_exact(8)
                .map(|c| {
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                        as i32
                })
                .collect(),
        },
        d => bail!("unsupported npy dtype {d:?}"),
    })
}

/// Extract a field value substring from the python-dict-literal header.
fn dict_field<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("'{key}':");
    let start = header
        .find(&pat)
        .ok_or_else(|| anyhow!("npy header missing {key:?}"))?
        + pat.len();
    let rest = &header[start..];
    // value ends at the next top-level comma or closing brace
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => return Ok(rest[..i].trim()),
            _ => {}
        }
    }
    Ok(rest.trim())
}

// --------------------------------------------------------------------------
// npz (zip container)
// --------------------------------------------------------------------------

/// Write named arrays to an npz archive (stored, like `np.savez`).
pub fn write_npz(path: impl AsRef<Path>, arrays: &[(&str, NpyArray)]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut z = zip::ZipWriter::new(file);
    let opts = zip::write::FileOptions::default()
        .compression_method(zip::CompressionMethod::Stored);
    for (name, arr) in arrays {
        z.start_file(format!("{name}.npy"), opts)?;
        z.write_all(&write_npy_bytes(arr))?;
    }
    z.finish()?;
    Ok(())
}

/// Read all arrays from an npz archive.
pub fn read_npz(path: impl AsRef<Path>) -> Result<Vec<(String, NpyArray)>> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut z = zip::ZipArchive::new(file)?;
    let mut out = Vec::with_capacity(z.len());
    for i in 0..z.len() {
        let mut entry = z.by_index(i)?;
        let name = entry
            .name()
            .trim_end_matches(".npy")
            .to_string();
        let mut bytes = Vec::with_capacity(entry.size() as usize);
        entry.read_to_end(&mut bytes)?;
        out.push((name, parse_npy_bytes(&bytes)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_roundtrip_f32() {
        let arr = NpyArray::F32 {
            shape: vec![2, 3],
            data: vec![1.5, -2.0, 3.25, 0.0, 1e-9, 7.0],
        };
        let bytes = write_npy_bytes(&arr);
        assert_eq!(parse_npy_bytes(&bytes).unwrap(), arr);
    }

    #[test]
    fn npy_roundtrip_i32_1d() {
        let arr = NpyArray::I32 {
            shape: vec![4],
            data: vec![1, -2, 3, i32::MAX],
        };
        let bytes = write_npy_bytes(&arr);
        assert_eq!(parse_npy_bytes(&bytes).unwrap(), arr);
    }

    #[test]
    fn npz_roundtrip_multiple() {
        let dir = std::env::temp_dir().join("slimadam_npz_test");
        let path = dir.join("x.npz");
        let a = NpyArray::F32 {
            shape: vec![2, 2],
            data: vec![1., 2., 3., 4.],
        };
        let b = NpyArray::I32 {
            shape: vec![3],
            data: vec![7, 8, 9],
        };
        write_npz(&path, &[("alpha", a.clone()), ("beta", b.clone())]).unwrap();
        let back = read_npz(&path).unwrap();
        assert_eq!(back.len(), 2);
        let map: std::collections::HashMap<_, _> = back.into_iter().collect();
        assert_eq!(map["alpha"], a);
        assert_eq!(map["beta"], b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_numpy_fixture_archives() {
        // real archives produced by python/compile/aot.py (skip when absent)
        let p = std::path::Path::new("artifacts/fixtures/linear2_v64.params.npz");
        if !p.exists() {
            return;
        }
        let arrays = read_npz(p).unwrap();
        assert_eq!(arrays.len(), 2);
        let map: std::collections::HashMap<_, _> = arrays.into_iter().collect();
        let (shape, data) = map["tok_embd"].as_f32().unwrap();
        assert_eq!(shape, &[64, 128]);
        assert!(data.iter().all(|x| x.is_finite()));
        let batches = read_npz("artifacts/fixtures/linear2_v64.batches.npz").unwrap();
        assert!(!batches.is_empty());
        let (_s, xs) = batches
            .iter()
            .find(|(n, _)| n == "x0")
            .unwrap()
            .1
            .as_i32()
            .unwrap();
        assert!(xs.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn header_parser_handles_order_variants() {
        let h = "{'shape': (3, 4), 'fortran_order': False, 'descr': '<f4', }";
        assert_eq!(dict_field(h, "descr").unwrap(), "'<f4'");
        assert_eq!(dict_field(h, "shape").unwrap(), "(3, 4)");
        assert_eq!(dict_field(h, "fortran_order").unwrap(), "False");
    }
}
