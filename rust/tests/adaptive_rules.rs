//! Property layer for the adaptive rule-switching controller
//! (DESIGN.md §18): arbitrary SNR traces through the in-repo property
//! harness must never violate the hysteresis/patience contract, and the
//! decision sequence must be a pure function of the trace — the
//! replay-determinism guarantee the resume and serve paths rely on.

use slimadam::optim::KMode;
use slimadam::proptest::{check, prop_assert, Gen};
use slimadam::rules::adaptive::{
    AdaptivePolicy, Controller, Decision, Direction, Mode,
};

/// A random valid policy with a non-degenerate hysteresis band and
/// `every = 1` (traces index evals directly; cadence is exercised by
/// `due` separately).
fn arbitrary_policy(g: &mut Gen) -> AdaptivePolicy {
    let enter = g.f64(0.5, 2.0);
    let p = AdaptivePolicy {
        enter,
        exit: enter * g.f64(0.0, 0.9),
        patience: g.usize(1, 4),
        every: 1,
    };
    p.validate().expect("generated policy must be valid");
    p
}

/// Random per-tensor targets: a mix of ruled modes and inert (`None`)
/// slots, at least one of each where the size allows.
fn arbitrary_targets(g: &mut Gen, n: usize) -> Vec<KMode> {
    (0..n)
        .map(|i| {
            if i == 0 {
                KMode::None // always at least one inert tensor
            } else {
                *g.choice(&[KMode::None, KMode::FanIn, KMode::FanOut, KMode::Both])
            }
        })
        .collect()
}

fn names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("t{i}")).collect()
}

/// One random SNR reading: below the exit edge, inside the band, above
/// the enter edge, or NaN (which the controller treats as in-band).
fn arbitrary_reading(g: &mut Gen, p: &AdaptivePolicy) -> f64 {
    match g.usize(0, 9) {
        0 => f64::NAN,
        1..=3 => p.exit - g.f64(1e-3, 1.0),                    // out: decompress side
        4..=6 => p.enter + g.f64(0.0, 1.0),                    // out: compress side
        _ => p.exit + (p.enter - p.exit) * g.f64(0.0, 0.95),   // in-band
    }
}

fn arbitrary_trace(g: &mut Gen, p: &AdaptivePolicy, n: usize, evals: usize) -> Vec<Vec<f64>> {
    (0..evals)
        .map(|_| (0..n).map(|_| arbitrary_reading(g, p)).collect())
        .collect()
}

/// Run a fresh controller over a trace (eval `e` observes at step `e+1`).
fn drive(p: AdaptivePolicy, targets: &[KMode], trace: &[Vec<f64>]) -> Controller {
    let mut c = Controller::slim_start(p, names(targets.len()), targets.to_vec());
    for (e, snrs) in trace.iter().enumerate() {
        c.observe(e + 1, snrs);
    }
    c
}

/// Was `snr` out-of-band for a tensor sitting in `mode`?
fn out_of_band(p: &AdaptivePolicy, mode: Mode, snr: f64) -> bool {
    match mode {
        Mode::Reduced => snr < p.exit,
        Mode::Full => snr >= p.enter,
    }
}

/// Readings confined to the hysteresis band `[exit, enter)` can never
/// switch anything, however long the run and whatever the patience.
#[test]
fn no_flapping_inside_the_band() {
    check(60, |g| {
        let p = arbitrary_policy(g);
        let n = g.usize(1, 8);
        let targets = arbitrary_targets(g, n);
        let evals = g.usize(1, 60);
        let trace: Vec<Vec<f64>> = (0..evals)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        if g.usize(0, 9) == 0 {
                            f64::NAN // NaN counts as in-band by contract
                        } else {
                            p.exit + (p.enter - p.exit) * g.f64(0.0, 0.95)
                        }
                    })
                    .collect()
            })
            .collect();
        let c = drive(p, &targets, &trace);
        prop_assert(c.log().is_empty(), format!("{:?}", c.log()))?;
        for (i, &k) in targets.iter().enumerate() {
            let want = if k == KMode::None { Mode::Full } else { Mode::Reduced };
            prop_assert(c.mode(i) == want, format!("tensor {i} moved"))?;
        }
        Ok(())
    });
}

/// Every logged decision was earned: the `patience` evals ending at the
/// decision were all out-of-band for the mode the tensor held, and none
/// of that window overlaps a previous decision on the same tensor. This
/// is checked purely against the trace — no controller internals.
#[test]
fn decisions_require_full_patience_streaks() {
    check(80, |g| {
        let p = arbitrary_policy(g);
        let n = g.usize(1, 8);
        let targets = arbitrary_targets(g, n);
        let trace = arbitrary_trace(g, &p, n, g.usize(1, 60));
        let c = drive(p, &targets, &trace);

        let mut prev_eval = vec![0usize; n]; // last decision eval per tensor
        for d in c.log() {
            let before = match d.dir {
                Direction::Compress => Mode::Full,
                Direction::Decompress => Mode::Reduced,
            };
            prop_assert(
                d.step >= p.patience,
                format!("decision at eval {} before patience {}", d.step, p.patience),
            )?;
            let window = d.step - p.patience + 1..=d.step;
            prop_assert(
                *window.start() > prev_eval[d.tensor],
                format!("streak for {} spans a previous decision", d.name),
            )?;
            for e in window {
                prop_assert(
                    out_of_band(&p, before, trace[e - 1][d.tensor]),
                    format!(
                        "eval {e} reading {} was in-band yet counted toward a \
                         {:?} at eval {}",
                        trace[e - 1][d.tensor],
                        d.dir,
                        d.step
                    ),
                )?;
            }
            prev_eval[d.tensor] = d.step;
        }
        Ok(())
    });
}

/// Per-tensor decisions strictly alternate direction, starting opposite
/// the start mode (ruled tensors boot Reduced, so their first switch is
/// always a Decompress), and consecutive switches on one tensor are at
/// least `patience` evals apart — the no-flapping guarantee.
#[test]
fn directions_alternate_with_min_gap() {
    check(80, |g| {
        let p = arbitrary_policy(g);
        let n = g.usize(1, 8);
        let targets = arbitrary_targets(g, n);
        let trace = arbitrary_trace(g, &p, n, g.usize(1, 80));
        let c = drive(p, &targets, &trace);

        for i in 0..n {
            let mine: Vec<&Decision> = c.log().iter().filter(|d| d.tensor == i).collect();
            if targets[i] == KMode::None {
                prop_assert(mine.is_empty(), format!("inert tensor {i} fired"))?;
                continue;
            }
            for (j, d) in mine.iter().enumerate() {
                let want = if j % 2 == 0 {
                    Direction::Decompress // slim_start: Reduced first
                } else {
                    Direction::Compress
                };
                prop_assert(d.dir == want, format!("tensor {i} switch {j}: {:?}", d.dir))?;
                if j > 0 {
                    let gap = d.step - mine[j - 1].step;
                    prop_assert(
                        gap >= p.patience,
                        format!("tensor {i} flapped: gap {gap} < patience {}", p.patience),
                    )?;
                }
            }
            // final mode consistent with the switch count
            let want = if mine.len() % 2 == 0 { Mode::Reduced } else { Mode::Full };
            prop_assert(c.mode(i) == want, format!("tensor {i} mode vs log parity"))?;
        }
        Ok(())
    });
}

/// Replay determinism: the decision log, final modes, and compression
/// count are a pure function of the observation trace. A second fresh
/// controller fed the same trace reproduces all of it exactly — the
/// contract `--resume` relies on to restore adaptive state without
/// re-executing steps.
#[test]
fn decision_sequence_is_pure_function_of_trace() {
    check(60, |g| {
        let p = arbitrary_policy(g);
        let n = g.usize(1, 8);
        let targets = arbitrary_targets(g, n);
        let trace = arbitrary_trace(g, &p, n, g.usize(1, 60));
        let a = drive(p, &targets, &trace);
        let b = drive(p, &targets, &trace);
        prop_assert(a.log() == b.log(), "replayed log differs")?;
        prop_assert(a.evals() == b.evals(), "replayed eval count differs")?;
        prop_assert(a.n_compressed() == b.n_compressed(), "replayed n_compressed differs")?;
        for i in 0..n {
            prop_assert(a.mode(i) == b.mode(i), format!("tensor {i} mode differs"))?;
            prop_assert(
                a.current_k(i) == b.current_k(i),
                format!("tensor {i} current_k differs"),
            )?;
        }
        // and the serialized checkpoint form round-trips the same log
        let dumped = a.log_json().dump();
        let parsed = slimadam::json::Value::parse(&dumped).map_err(|e| format!("{e:#}"))?;
        let back: Vec<Decision> = parsed
            .as_arr()
            .map_err(|e| format!("{e:#}"))?
            .iter()
            .map(|v| Decision::from_json(v).map_err(|e| format!("{e:#}")))
            .collect::<Result<_, String>>()?;
        prop_assert(back == a.log(), "log JSON roundtrip differs")
    });
}

/// Deterministic square-wave trace: a hand-computable decision schedule.
/// Low for `patience` evals → decompress exactly then; high for
/// `patience` evals → compress exactly then; repeat. Locks the exact
/// firing step arithmetic (off-by-one regressions show up here first).
#[test]
fn square_wave_switches_on_schedule() {
    let p = AdaptivePolicy { enter: 1.0, exit: 0.25, patience: 3, every: 1 };
    let mut c = Controller::slim_start(p, names(1), vec![KMode::FanOut]);
    let mut step = 0;
    let mut expect = Vec::new();
    for cycle in 0..4 {
        let (snr, dir) = if cycle % 2 == 0 {
            (0.1, Direction::Decompress)
        } else {
            (2.0, Direction::Compress)
        };
        for j in 1..=p.patience {
            step += 1;
            let fired = c.observe(step, &[snr]);
            if j < p.patience {
                assert!(fired.is_empty(), "early fire at step {step}");
            } else {
                assert_eq!(fired.len(), 1, "no fire at step {step}");
                assert_eq!(fired[0].dir, dir);
                expect.push((step, dir));
            }
        }
    }
    let got: Vec<(usize, Direction)> = c.log().iter().map(|d| (d.step, d.dir)).collect();
    assert_eq!(got, expect);
    assert_eq!(c.evals(), 4 * p.patience);
}

/// `due` honors the cadence for any `every`, and policy specs round-trip
/// through parse for arbitrary valid values.
#[test]
fn cadence_and_spec_roundtrip() {
    check(60, |g| {
        let mut p = arbitrary_policy(g);
        p.every = g.usize(1, 50);
        let c = Controller::slim_start(p, names(1), vec![KMode::Both]);
        for step in 1..=200 {
            prop_assert(
                c.due(step) == (step % p.every == 0),
                format!("due({step}) with every={}", p.every),
            )?;
        }
        let back = AdaptivePolicy::parse(&p.spec()).map_err(|e| format!("{e:#}"))?;
        prop_assert(back == p, format!("{} reparsed as {}", p.spec(), back.spec()))?;
        let back = AdaptivePolicy::from_key(&p.key()).map_err(|e| format!("{e:#}"))?;
        prop_assert(back == p, "key roundtrip")
    });
}

/// Kill-and-resume with live mode switches (the runstore_resume.rs
/// cycle, on real native training — this binary never enables synthetic
/// mode, so the adaptive reports are real): an interrupted adaptive
/// sweep resumes with zero re-execution, the re-executed job's
/// controller replays to the identical decision log, and the stored
/// rows' "adaptive" payloads match the uninterrupted reference byte for
/// byte. Uses the always-decompress policy (`exit = +inf`: any finite
/// SNR reading is below it; `enter = +inf`: compression can never
/// re-fire) so a mode switch is guaranteed at the first eval.
#[test]
fn killed_adaptive_sweep_resumes_with_replayed_decisions() {
    use slimadam::coordinator::{EngineKind, SweepScheduler, TrainConfig};
    use slimadam::json::Value;
    use slimadam::runstore::{config_key, RunStore};
    use slimadam::runtime::backend::BackendSpec;

    assert!(!slimadam::coordinator::synthetic_runs_enabled());
    let policy = AdaptivePolicy {
        enter: f64::INFINITY,
        exit: f64::INFINITY,
        patience: 1,
        every: 2,
    };
    let configs: Vec<TrainConfig> = [8e-4, 1e-3, 2e-3]
        .iter()
        .map(|&lr| {
            let mut cfg = TrainConfig::auto("gpt_micro", "adam", lr, 6);
            cfg.backend = BackendSpec::native();
            cfg.engine = EngineKind::Fused("slimadam".to_string());
            cfg.adaptive = Some(policy);
            cfg
        })
        .collect();

    let tmpdir = |name: &str| {
        let dir = std::env::temp_dir().join(format!("slimadam_adaptive_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    };
    // the "adaptive" payload of the stored row for one config key
    let stored_adaptive = |store: &RunStore, key: u64| -> String {
        let hex = format!("{key:016x}");
        let text = std::fs::read_to_string(store.primary()).unwrap();
        for line in text.lines() {
            let Ok(row) = Value::parse(line) else { continue };
            if row.get("config_key").and_then(|k| k.as_str().map(String::from)).ok()
                == Some(hex.clone())
            {
                return row.get("adaptive").expect("adaptive row payload").dump();
            }
        }
        panic!("no stored row for {hex}");
    };

    // --- reference: uninterrupted sweep ---
    let ref_dir = tmpdir("reference");
    let ref_store = RunStore::open(&ref_dir).unwrap();
    let reference = SweepScheduler::new(1)
        .quiet()
        .stream_to(ref_store.primary())
        .run(&configs)
        .unwrap();
    for s in &reference {
        let rep = s.adaptive.as_ref().expect("adaptive report");
        assert!(!rep.decisions.is_empty(), "{}: no switch fired", s.label);
        assert!(
            rep.decisions.iter().all(|d| d.dir == Direction::Decompress),
            "{}: {:?}",
            s.label,
            rep.decisions
        );
        // everything decompressed: storage is back at the Adam baseline
        assert_eq!(rep.final_v_elems, rep.full_v_elems, "{}", s.label);
        assert_eq!(rep.compressed_frac, 0.0, "{}", s.label);
        assert!(rep.timeline.len() >= 2, "{}: {:?}", s.label, rep.timeline);
    }

    // --- interrupted: 2 of 3 jobs complete, then a kill tears the tail ---
    let dir = tmpdir("interrupted");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..2])
        .unwrap();
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(store.primary())
            .unwrap();
        f.write_all(b"{\"label\":\"gpt_micro/adam@lr2e-3+ad\",\"adaptive\":{\"dec")
            .unwrap();
    }

    // --- resume over the full grid: zero re-execution of stored jobs ---
    let resumed = SweepScheduler::new(1)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    assert_eq!(resumed.iter().filter(|s| s.restored()).count(), 2);
    for (r, s) in resumed.iter().zip(&reference) {
        assert_eq!(r.fingerprint(), s.fingerprint(), "{}", s.label);
    }
    let idx = store.index().unwrap();
    assert_eq!(idx.len(), configs.len());
    assert_eq!(idx.stats.torn + idx.stats.skipped, 0, "torn tail repaired");
    for cfg in &configs {
        assert!(idx.contains(config_key(cfg)));
    }

    // the re-executed job replayed the controller to the identical state
    let live = resumed[2].adaptive.as_ref().expect("live adaptive report");
    assert_eq!(live, reference[2].adaptive.as_ref().unwrap());

    // and its stored row carries the same decision payload byte for byte
    let key = config_key(&configs[2]);
    assert_eq!(
        stored_adaptive(&store, key),
        stored_adaptive(&ref_store, key),
        "stored adaptive payloads must replay identically"
    );

    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The never-fire differential policy is inert for every finite or
/// non-finite reading pattern — the guarantee that makes `--adaptive`
/// with it bit-identical to static SlimAdam.
#[test]
fn never_fire_policy_never_fires() {
    check(40, |g| {
        let n = g.usize(1, 6);
        let targets = arbitrary_targets(g, n);
        let evals = g.usize(1, 40);
        let trace: Vec<Vec<f64>> = (0..evals)
            .map(|_| {
                (0..n)
                    .map(|_| match g.usize(0, 4) {
                        0 => f64::NAN,
                        1 => f64::INFINITY,
                        2 => f64::NEG_INFINITY,
                        _ => g.f64(-1e6, 1e6),
                    })
                    .collect()
            })
            .collect();
        let c = drive(AdaptivePolicy::never_fire(), &targets, &trace);
        prop_assert(c.log().is_empty(), format!("{:?}", c.log()))?;
        let ruled = targets.iter().filter(|&&k| k != KMode::None).count();
        prop_assert(c.n_compressed() == ruled, "ruled tensors must stay compressed")
    });
}
