//! Resume determinism (DESIGN.md §10, ISSUE acceptance criterion): an
//! interrupted sweep resumed via the run store must produce a result set
//! byte-identical — per `RunResult::fingerprint` — to an uninterrupted
//! run, while re-executing zero already-completed jobs; torn trailing
//! JSONL lines are recovered, not fatal.
//!
//! These tests run without artifacts: `SLIMADAM_SYNTH_RUNS=1` switches
//! `run_config` to its deterministic synthetic mode (a pure function of
//! the config, exactly like a real run), so the whole
//! run → kill → truncate → resume cycle is exercised in plain CI.

use std::fs;
use std::path::PathBuf;

use slimadam::coordinator::{SweepScheduler, TrainConfig};
use slimadam::runstore::{config_key, RunStore};

fn enable_synth() {
    // Safe here: every test in this binary sets the same value, and
    // nothing in the crate mutates the environment concurrently.
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("slimadam_resume_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// The sweep grid under test: 2 optimizers × 3 LRs, including one
/// diverging point (lr > 3e-2 in synthetic mode).
fn grid() -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [1e-3, 3e-3, 5e-2] {
            configs.push(TrainConfig::lm("gpt_nano", opt, lr, 24));
        }
    }
    configs
}

#[test]
fn synthetic_runs_are_deterministic() {
    enable_synth();
    let cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 24);
    let a = slimadam::coordinator::run_config(&cfg).unwrap();
    let b = slimadam::coordinator::run_config(&cfg).unwrap();
    assert_eq!(a.result.fingerprint(), b.result.fingerprint());
    assert_eq!(a.result.losses, b.result.losses);
    // and sensitive to the config
    let mut other = cfg.clone();
    other.lr = 3e-3;
    let c = slimadam::coordinator::run_config(&other).unwrap();
    assert_ne!(a.result.fingerprint(), c.result.fingerprint());
}

/// The full acceptance cycle: run a complete sweep (reference), then an
/// interrupted one (partial rows + a torn tail), resume it, and compare
/// the merged store against the reference store.
#[test]
fn interrupted_sweep_resumes_byte_identical() {
    enable_synth();
    let configs = grid();

    // --- reference: uninterrupted serial sweep ---
    let ref_dir = tmpdir("reference");
    let ref_store = RunStore::open(&ref_dir).unwrap();
    let ref_summaries = SweepScheduler::new(1)
        .quiet()
        .stream_to(ref_store.primary())
        .run(&configs)
        .unwrap();
    assert_eq!(ref_summaries.len(), configs.len());
    let ref_index = ref_store.index().unwrap();
    assert_eq!(ref_index.len(), configs.len());

    // --- interrupted: first 4 jobs complete, then a crash tears the tail ---
    let dir = tmpdir("interrupted");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..4])
        .unwrap();
    {
        use std::io::Write;
        let mut f = fs::OpenOptions::new()
            .append(true)
            .open(store.primary())
            .unwrap();
        // a SIGKILL mid-write: a prefix of a row, no newline
        f.write_all(b"{\"label\":\"gpt_nano/adam@lr5e-2\",\"final_tr").unwrap();
    }

    // --- resume over the full grid ---
    let resumed = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    // zero re-execution: exactly the 4 completed jobs restored
    let restored = resumed.iter().filter(|s| s.restored()).count();
    assert_eq!(restored, 4, "completed jobs must not re-execute");
    assert_eq!(
        resumed.iter().filter(|s| !s.restored()).count(),
        configs.len() - 4
    );

    // merged store is byte-identical to the uninterrupted run
    let index = store.index().unwrap();
    assert_eq!(index.fingerprints(), ref_index.fingerprints());
    assert_eq!(index.stats.torn + index.stats.skipped, 0, "tail repaired");

    // every config appears exactly once in the merged stream
    assert_eq!(index.len(), configs.len());
    assert_eq!(index.stats.duplicates + index.stats.conflicts, 0);

    // and the in-memory result set matches the reference job-for-job
    for (r, s) in resumed.iter().zip(&ref_summaries) {
        assert_eq!(r.fingerprint(), s.fingerprint(), "{}", s.label);
        assert_eq!(r.lr, s.lr);
        assert_eq!(r.result.diverged, s.result.diverged);
    }

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_dir_all(&dir);
}

/// Resuming a store where *everything* finished runs nothing and still
/// returns the full result set.
#[test]
fn fully_complete_store_skips_everything() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("complete");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(2)
        .quiet()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    let resumed = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    assert!(resumed.iter().all(|s| s.restored()));
    // no duplicate rows were appended
    let index = store.index().unwrap();
    assert_eq!(index.len(), configs.len());
    assert_eq!(index.stats.duplicates, 0);
    let _ = fs::remove_dir_all(&dir);
}

/// skip_mask consults config identity, not grid position: reordering the
/// grid or changing a config invalidates only the affected entries.
#[test]
fn skip_mask_tracks_config_identity() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("mask");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..3])
        .unwrap();
    let index = store.index().unwrap();

    assert_eq!(index.skip_mask(&configs), vec![true, true, true, false, false, false]);

    // reordered grid: membership follows the config, not the slot
    let mut reordered = configs.clone();
    reordered.reverse();
    let mask = index.skip_mask(&reordered);
    assert_eq!(mask, vec![false, false, false, true, true, true]);

    // a changed seed is a different job
    let mut changed = configs[0].clone();
    changed.seed = 99;
    assert!(!index.contains(config_key(&changed)));
    let _ = fs::remove_dir_all(&dir);
}

/// Restored summaries preserve the scalar metrics the store carries.
#[test]
fn restored_summaries_carry_store_metrics() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("metrics");
    let store = RunStore::open(&dir).unwrap();
    let live = SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    let resumed = SweepScheduler::new(1)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .run(&configs)
        .unwrap();
    // Finite metrics round-trip through JSON exactly (shortest-repr f64
    // writer); non-finite ones (diverged points) pass through the -1.0
    // row sentinel and restore as NaN.
    let matches = |restored: f64, lived: f64| {
        restored == lived || (restored.is_nan() && !lived.is_finite())
    };
    for (r, l) in resumed.iter().zip(&live) {
        assert!(r.restored());
        assert_eq!(r.label, l.label);
        assert_eq!(r.model, l.model);
        assert_eq!(r.optimizer, l.optimizer);
        assert_eq!(r.result.diverged, l.result.diverged);
        assert!(
            matches(r.result.final_train_loss, l.result.final_train_loss),
            "{}: {} vs {}",
            l.label,
            r.result.final_train_loss,
            l.result.final_train_loss
        );
        assert!(
            matches(r.result.eval_loss, l.result.eval_loss),
            "{}: {} vs {}",
            l.label,
            r.result.eval_loss,
            l.result.eval_loss
        );
    }
    // the grid's diverged point must be restorable (its row carries the
    // sentinel, not an unindexable null) — the resume-coverage gap a
    // finite-only synthetic mode would hide
    assert!(live.iter().any(|s| s.result.diverged));
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Batched-writer append-order regression (ISSUE 4 satellite): a batched
// sweep streams each dispatch group's rows under one writer lock, but
// concurrent workers still interleave *groups*, older stores may hold
// arbitrary interleavings, and a kill can tear the file mid-batch. The
// run index must not care: membership, first-wins dedup and conflict
// counts are a function of the row multiset, never of append order.
// ---------------------------------------------------------------------------

fn synth_row(key: u64, fp: u64) -> String {
    format!(
        r#"{{"config_key":"{key:016x}","fingerprint":"{fp:016x}","seed":"002a","job":0,"label":"m/adam@lr1e-3","model":"m","optimizer":"adam","lr":0.001,"final_train_loss":1.5,"eval_loss":1.6,"diverged":false,"steps":10}}"#
    )
}

/// Index identity is append-order-invariant: the same rows written in
/// group order, interleaved across groups, or fully reversed — across
/// one or two stream files — index identically.
#[test]
fn index_is_stable_under_interleaved_batched_append_order() {
    // two 4-job "groups" plus one duplicate row (a resumed re-append)
    let g1: Vec<String> = (0..4).map(|i| synth_row(i, 100 + i)).collect();
    let g2: Vec<String> = (4..8).map(|i| synth_row(i, 100 + i)).collect();
    let dup = synth_row(2, 102);

    let grouped: Vec<&str> = g1.iter().chain(&g2).chain([&dup]).map(|s| s.as_str()).collect();
    let interleaved: Vec<&str> = vec![
        &g1[0], &g2[0], &g1[1], &g2[1], &dup, &g1[2], &g2[2], &g1[3], &g2[3],
    ]
    .into_iter()
    .map(|s| s.as_str())
    .collect();
    let mut reversed = grouped.clone();
    reversed.reverse();

    let mut identities = Vec::new();
    for (name, order) in [
        ("grouped", &grouped),
        ("interleaved", &interleaved),
        ("reversed", &reversed),
    ] {
        let dir = tmpdir(&format!("interleave_{name}"));
        // split the same order across two stream files, like a sweep
        // that crashed and resumed into a second stream
        let (a, b) = order.split_at(order.len() / 2);
        std::fs::write(dir.join("a.jsonl"), format!("{}\n", a.join("\n"))).unwrap();
        std::fs::write(dir.join("b.jsonl"), format!("{}\n", b.join("\n"))).unwrap();
        let store = RunStore::open(&dir).unwrap();
        let idx = store.index().unwrap();
        assert_eq!(idx.len(), 8, "{name}");
        assert_eq!(idx.stats.duplicates, 1, "{name}");
        assert_eq!(idx.stats.conflicts, 0, "{name}");
        identities.push(idx.fingerprints());
        let _ = std::fs::remove_dir_all(&dir);
    }
    assert_eq!(identities[0], identities[1]);
    assert_eq!(identities[0], identities[2]);
}

/// Conflicting duplicates (same config key, different fingerprint) are
/// counted identically regardless of which interleaving the writers
/// produced, and a tail torn mid-batch neither hides rows nor miscounts.
#[test]
fn conflict_counts_and_torn_mid_batch_are_order_stable() {
    let rows: Vec<String> = (0..4).map(|i| synth_row(i, 100 + i)).collect();
    let conflict = synth_row(1, 0xdead); // disagrees with row 1

    for (name, order) in [
        ("early", vec![&conflict, &rows[0], &rows[1], &rows[2], &rows[3]]),
        ("late", vec![&rows[0], &rows[1], &rows[2], &rows[3], &conflict]),
        ("mid", vec![&rows[0], &rows[1], &conflict, &rows[2], &rows[3]]),
    ] {
        let dir = tmpdir(&format!("conflict_{name}"));
        let mut text = order
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        text.push('\n');
        // a SIGKILL mid-batch: the next group's first row is torn at EOF
        text.push_str("{\"config_key\":\"00000000000000ff\",\"finger");
        std::fs::write(dir.join("stream.jsonl"), &text).unwrap();

        let store = RunStore::open(&dir).unwrap();
        let idx = store.index().unwrap();
        assert_eq!(idx.len(), 4, "{name}: complete rows all indexed");
        assert_eq!(idx.stats.conflicts, 1, "{name}");
        assert_eq!(idx.stats.duplicates, 0, "{name}");
        assert_eq!(idx.stats.torn, 1, "{name}: torn mid-batch tail recovered");
        // repair + reindex: the torn fragment is gone, counts unchanged
        assert_eq!(store.repair_tails().unwrap(), 1, "{name}");
        let idx2 = store.index().unwrap();
        assert_eq!(idx2.len(), 4, "{name}");
        assert_eq!(idx2.stats.conflicts, 1, "{name}");
        assert_eq!(idx2.stats.torn, 0, "{name}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
