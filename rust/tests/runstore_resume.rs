//! Resume determinism (DESIGN.md §10, ISSUE acceptance criterion): an
//! interrupted sweep resumed via the run store must produce a result set
//! byte-identical — per `RunResult::fingerprint` — to an uninterrupted
//! run, while re-executing zero already-completed jobs; torn trailing
//! JSONL lines are recovered, not fatal.
//!
//! These tests run without artifacts: `SLIMADAM_SYNTH_RUNS=1` switches
//! `run_config` to its deterministic synthetic mode (a pure function of
//! the config, exactly like a real run), so the whole
//! run → kill → truncate → resume cycle is exercised in plain CI.

use std::fs;
use std::path::PathBuf;

use slimadam::coordinator::{SweepScheduler, TrainConfig};
use slimadam::runstore::{config_key, RunStore};

fn enable_synth() {
    // Safe here: every test in this binary sets the same value, and
    // nothing in the crate mutates the environment concurrently.
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("slimadam_resume_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// The sweep grid under test: 2 optimizers × 3 LRs, including one
/// diverging point (lr > 3e-2 in synthetic mode).
fn grid() -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [1e-3, 3e-3, 5e-2] {
            configs.push(TrainConfig::lm("gpt_nano", opt, lr, 24));
        }
    }
    configs
}

#[test]
fn synthetic_runs_are_deterministic() {
    enable_synth();
    let cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 24);
    let a = slimadam::coordinator::run_config(&cfg).unwrap();
    let b = slimadam::coordinator::run_config(&cfg).unwrap();
    assert_eq!(a.result.fingerprint(), b.result.fingerprint());
    assert_eq!(a.result.losses, b.result.losses);
    // and sensitive to the config
    let mut other = cfg.clone();
    other.lr = 3e-3;
    let c = slimadam::coordinator::run_config(&other).unwrap();
    assert_ne!(a.result.fingerprint(), c.result.fingerprint());
}

/// The full acceptance cycle: run a complete sweep (reference), then an
/// interrupted one (partial rows + a torn tail), resume it, and compare
/// the merged store against the reference store.
#[test]
fn interrupted_sweep_resumes_byte_identical() {
    enable_synth();
    let configs = grid();

    // --- reference: uninterrupted serial sweep ---
    let ref_dir = tmpdir("reference");
    let ref_store = RunStore::open(&ref_dir).unwrap();
    let ref_summaries = SweepScheduler::new(1)
        .quiet()
        .stream_to(ref_store.primary())
        .run(&configs)
        .unwrap();
    assert_eq!(ref_summaries.len(), configs.len());
    let ref_index = ref_store.index().unwrap();
    assert_eq!(ref_index.len(), configs.len());

    // --- interrupted: first 4 jobs complete, then a crash tears the tail ---
    let dir = tmpdir("interrupted");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..4])
        .unwrap();
    {
        use std::io::Write;
        let mut f = fs::OpenOptions::new()
            .append(true)
            .open(store.primary())
            .unwrap();
        // a SIGKILL mid-write: a prefix of a row, no newline
        f.write_all(b"{\"label\":\"gpt_nano/adam@lr5e-2\",\"final_tr").unwrap();
    }

    // --- resume over the full grid ---
    let resumed = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    // zero re-execution: exactly the 4 completed jobs restored
    let restored = resumed.iter().filter(|s| s.restored()).count();
    assert_eq!(restored, 4, "completed jobs must not re-execute");
    assert_eq!(
        resumed.iter().filter(|s| !s.restored()).count(),
        configs.len() - 4
    );

    // merged store is byte-identical to the uninterrupted run
    let index = store.index().unwrap();
    assert_eq!(index.fingerprints(), ref_index.fingerprints());
    assert_eq!(index.stats.torn + index.stats.skipped, 0, "tail repaired");

    // every config appears exactly once in the merged stream
    assert_eq!(index.len(), configs.len());
    assert_eq!(index.stats.duplicates + index.stats.conflicts, 0);

    // and the in-memory result set matches the reference job-for-job
    for (r, s) in resumed.iter().zip(&ref_summaries) {
        assert_eq!(r.fingerprint(), s.fingerprint(), "{}", s.label);
        assert_eq!(r.lr, s.lr);
        assert_eq!(r.result.diverged, s.result.diverged);
    }

    let _ = fs::remove_dir_all(&ref_dir);
    let _ = fs::remove_dir_all(&dir);
}

/// Resuming a store where *everything* finished runs nothing and still
/// returns the full result set.
#[test]
fn fully_complete_store_skips_everything() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("complete");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(2)
        .quiet()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    let resumed = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    assert!(resumed.iter().all(|s| s.restored()));
    // no duplicate rows were appended
    let index = store.index().unwrap();
    assert_eq!(index.len(), configs.len());
    assert_eq!(index.stats.duplicates, 0);
    let _ = fs::remove_dir_all(&dir);
}

/// skip_mask consults config identity, not grid position: reordering the
/// grid or changing a config invalidates only the affected entries.
#[test]
fn skip_mask_tracks_config_identity() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("mask");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..3])
        .unwrap();
    let index = store.index().unwrap();

    assert_eq!(index.skip_mask(&configs), vec![true, true, true, false, false, false]);

    // reordered grid: membership follows the config, not the slot
    let mut reordered = configs.clone();
    reordered.reverse();
    let mask = index.skip_mask(&reordered);
    assert_eq!(mask, vec![false, false, false, true, true, true]);

    // a changed seed is a different job
    let mut changed = configs[0].clone();
    changed.seed = 99;
    assert!(!index.contains(config_key(&changed)));
    let _ = fs::remove_dir_all(&dir);
}

/// Restored summaries preserve the scalar metrics the store carries.
#[test]
fn restored_summaries_carry_store_metrics() {
    enable_synth();
    let configs = grid();
    let dir = tmpdir("metrics");
    let store = RunStore::open(&dir).unwrap();
    let live = SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    let resumed = SweepScheduler::new(1)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .run(&configs)
        .unwrap();
    // Finite metrics round-trip through JSON exactly (shortest-repr f64
    // writer); non-finite ones (diverged points) pass through the -1.0
    // row sentinel and restore as NaN.
    let matches = |restored: f64, lived: f64| {
        restored == lived || (restored.is_nan() && !lived.is_finite())
    };
    for (r, l) in resumed.iter().zip(&live) {
        assert!(r.restored());
        assert_eq!(r.label, l.label);
        assert_eq!(r.model, l.model);
        assert_eq!(r.optimizer, l.optimizer);
        assert_eq!(r.result.diverged, l.result.diverged);
        assert!(
            matches(r.result.final_train_loss, l.result.final_train_loss),
            "{}: {} vs {}",
            l.label,
            r.result.final_train_loss,
            l.result.final_train_loss
        );
        assert!(
            matches(r.result.eval_loss, l.result.eval_loss),
            "{}: {} vs {}",
            l.label,
            r.result.eval_loss,
            l.result.eval_loss
        );
    }
    // the grid's diverged point must be restorable (its row carries the
    // sentinel, not an unindexable null) — the resume-coverage gap a
    // finite-only synthetic mode would hide
    assert!(live.iter().any(|s| s.result.diverged));
    let _ = fs::remove_dir_all(&dir);
}
