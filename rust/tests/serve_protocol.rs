//! Wire-protocol torn-message recovery (ISSUE 8, satellite): the serve
//! frame codec must survive any byte-level mutilation a killed peer can
//! produce — a stream cut at an arbitrary split point yields exactly the
//! frames that were fully delivered and then `Torn`/`Eof`, never a wrong
//! frame; a malformed line is rejected as one unit and the next frame
//! still decodes (no desync). Property-tested over every split point of
//! small streams and randomized splits of larger ones.

use slimadam::json::Value;
use slimadam::proptest::{check, prop_assert};
use slimadam::serve::proto::{encode, write_frame, FrameReader, Recv};

/// Encode a few distinguishable frames: `{"op":"ping","n":<i>,"tag":<s>}`.
fn frames(n: usize, tag: &str) -> (Vec<Value>, String) {
    let mut vals = Vec::new();
    let mut stream = String::new();
    for i in 0..n {
        let mut v = Value::obj();
        v.set("op", "ping").set("n", i).set("tag", tag);
        stream.push_str(&encode(&v));
        vals.push(v);
    }
    (vals, stream)
}

/// Drain a byte slice through the reader; returns (decoded frames, bad
/// count, ended torn).
fn drain(bytes: &[u8]) -> (Vec<Value>, usize, bool) {
    let mut reader = FrameReader::new(std::io::Cursor::new(bytes.to_vec()));
    let mut out = Vec::new();
    let mut bad = 0;
    loop {
        match reader.read_frame() {
            Recv::Frame(v) => out.push(v),
            Recv::Bad(_) => bad += 1,
            Recv::Torn => return (out, bad, true),
            Recv::Eof => return (out, bad, false),
        }
    }
}

#[test]
fn roundtrip_stream_decodes_in_order() {
    let (vals, stream) = frames(7, "order");
    let (got, bad, torn) = drain(stream.as_bytes());
    assert_eq!(bad, 0);
    assert!(!torn);
    assert_eq!(got.len(), vals.len());
    for (i, v) in got.iter().enumerate() {
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), i);
    }
}

/// Exhaustive split points: for EVERY prefix length of a 4-frame stream,
/// the reader yields exactly the fully-delivered frames, then reports the
/// cut (Torn mid-line, Eof at a boundary) — and never a mangled frame.
#[test]
fn every_split_point_recovers_cleanly() {
    let (_, stream) = frames(4, "split");
    let bytes = stream.as_bytes();
    // how many '\n'-terminated frames fit in each prefix
    for cut in 0..=bytes.len() {
        let complete = bytes[..cut].iter().filter(|&&b| b == b'\n').count();
        let (got, bad, torn) = drain(&bytes[..cut]);
        assert_eq!(bad, 0, "cut {cut}: a truncated line must be Torn, not Bad");
        assert_eq!(got.len(), complete, "cut {cut}");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.get("n").unwrap().as_usize().unwrap(), i, "cut {cut}");
        }
        let mid_line = cut > 0 && bytes[cut - 1] != b'\n';
        assert_eq!(torn, mid_line, "cut {cut}");
    }
}

/// A bad line (garbage, wrong length prefix, or spliced payload) is
/// rejected without desyncing: the frames after it still decode.
#[test]
fn bad_frames_do_not_desync_the_stream() {
    let mut v0 = Value::obj();
    v0.set("op", "ping").set("n", 0usize);
    let mut v1 = Value::obj();
    v1.set("op", "ping").set("n", 1usize);
    for garbage in [
        "not a frame\n",
        "9999 {\"op\":\"ping\"}\n",          // length prefix lies
        "3 {\"op\":\"ping\",\"n\":0}\n",     // too-short prefix
        "12 {\"op\":\"pi\n",                 // payload torn, line complete
        "\n",                                // empty line
    ] {
        let stream = format!("{}{garbage}{}", encode(&v0), encode(&v1));
        let (got, bad, torn) = drain(stream.as_bytes());
        assert!(!torn, "{garbage:?}");
        assert!(bad >= 1, "{garbage:?} must be rejected");
        assert_eq!(got.len(), 2, "{garbage:?} desynced the stream");
        assert_eq!(got[0].get("n").unwrap().as_usize().unwrap(), 0);
        assert_eq!(got[1].get("n").unwrap().as_usize().unwrap(), 1);
    }
}

/// Property: random frame streams with a random cut. The prefix before
/// the cut decodes to exactly the complete frames in order; nothing is
/// invented, reordered, or silently dropped.
#[test]
fn prop_random_streams_survive_random_cuts() {
    check(60, |g| {
        let n = g.usize(1, 6);
        let mut stream = String::new();
        let mut payload_ns = Vec::new();
        for i in 0..n {
            let mut v = Value::obj();
            v.set("op", "row").set("n", i).set("s", g.json_string(12));
            if g.bool() {
                v.set("x", g.f64(-1e6, 1e6));
            }
            stream.push_str(&encode(&v));
            payload_ns.push(i);
        }
        let bytes = stream.as_bytes();
        let cut = g.usize(0, bytes.len());
        let complete = bytes[..cut].iter().filter(|&&b| b == b'\n').count();
        let (got, bad, _) = drain(&bytes[..cut]);
        prop_assert(bad == 0, format!("cut {cut}: bad frames from a clean prefix"))?;
        prop_assert(
            got.len() == complete,
            format!("cut {cut}: {} frames, want {complete}", got.len()),
        )?;
        for (i, v) in got.iter().enumerate() {
            prop_assert(
                v.get("n").unwrap().as_usize().unwrap() == i,
                format!("cut {cut}: frame {i} out of order"),
            )?;
        }
        Ok(())
    });
}

/// Property: splicing two streams at newline boundaries (the only way
/// concurrent line-atomic writers can interleave) loses nothing.
#[test]
fn prop_interleaved_writers_never_corrupt() {
    check(40, |g| {
        let (a_vals, a) = frames(g.usize(1, 4), "a");
        let (b_vals, b) = frames(g.usize(1, 4), "b");
        // random riffle of whole lines
        let mut a_lines: Vec<&str> = a.split_inclusive('\n').collect();
        let mut b_lines: Vec<&str> = b.split_inclusive('\n').collect();
        let mut stream = String::new();
        while !a_lines.is_empty() || !b_lines.is_empty() {
            let take_a = !a_lines.is_empty() && (b_lines.is_empty() || g.bool());
            let src = if take_a { &mut a_lines } else { &mut b_lines };
            stream.push_str(src.remove(0));
        }
        let (got, bad, torn) = drain(stream.as_bytes());
        prop_assert(bad == 0 && !torn, "riffled stream must be clean".into())?;
        prop_assert(
            got.len() == a_vals.len() + b_vals.len(),
            format!("{} frames of {}", got.len(), a_vals.len() + b_vals.len()),
        )?;
        // per-tag order preserved
        for tag in ["a", "b"] {
            let ns: Vec<usize> = got
                .iter()
                .filter(|v| v.get("tag").unwrap().as_str().unwrap() == tag)
                .map(|v| v.get("n").unwrap().as_usize().unwrap())
                .collect();
            prop_assert(
                ns.iter().enumerate().all(|(i, &x)| i == x),
                format!("tag {tag} reordered: {ns:?}"),
            )?;
        }
        Ok(())
    });
}

/// write_frame over a real pipe-like buffer matches encode byte for byte.
#[test]
fn write_frame_matches_encode() {
    let mut v = Value::obj();
    v.set("op", "status");
    let mut buf: Vec<u8> = Vec::new();
    write_frame(&mut buf, &v).unwrap();
    assert_eq!(String::from_utf8(buf).unwrap(), encode(&v));
}
