//! Cross-layer numeric fixtures: `python/compile/aot.py` trains reference
//! models with plain-jnp AdamW and dumps initial params, batches and the
//! per-step loss sequence; this test replays the identical schedule through
//! a backend's grad_step + the Rust AdamK optimizer and requires the
//! losses to match — pinning the whole artifact→runtime→optimizer chain to
//! the Python ground truth.
//!
//! Two chains are pinned: the PJRT path (HLO artifacts from
//! `make artifacts`) and — since ISSUE 6 — the native interpreter's f64
//! path, via the `native_mlp` JAX model that mirrors the builtin
//! `mlp_tiny` family exactly. The native replay is the interpreter's
//! only check against an *external* ground truth (everything else is
//! finite differences or self-consistency).

use slimadam::npy::read_npz;
use slimadam::optim::{clip_global_norm, Hypers, KMode, Optimizer};
use slimadam::optim::adamk::AdamK;
use slimadam::runtime::backend::{backend_for, BackendSpec};
use slimadam::runtime::engine::{BatchData, GradEngine};
use slimadam::tensor::Tensor;

fn fixture_available(model: &str) -> bool {
    std::path::Path::new(&format!("artifacts/fixtures/{model}.fixture.json")).exists()
}

fn replay(model: &str, rtol: f32) {
    replay_on(&BackendSpec::pjrt(), model, model, rtol);
}

/// Replay fixture `fixture` through backend `spec`'s artifact for
/// `model`. The two names differ only for the native interpreter, whose
/// builtin models are named independently of the python fixture models.
fn replay_on(spec: &BackendSpec, model: &str, fixture: &str, rtol: f32) {
    let fix_text =
        std::fs::read_to_string(format!("artifacts/fixtures/{fixture}.fixture.json")).unwrap();
    let fix = slimadam::json::Value::parse(&fix_text).unwrap();
    let steps = fix.get("steps").unwrap().as_usize().unwrap();
    let lr = fix.get("lr").unwrap().as_f64().unwrap() as f32;
    let h = fix.get("hypers").unwrap();
    let hypers = Hypers {
        beta1: h.get("beta1").unwrap().as_f64().unwrap(),
        beta2: h.get("beta2").unwrap().as_f64().unwrap(),
        eps: h.get("eps").unwrap().as_f64().unwrap(),
        weight_decay: h.get("weight_decay").unwrap().as_f64().unwrap(),
        clip_norm: h.get("clip_norm").unwrap().as_f64().unwrap(),
    };
    let expected: Vec<f64> = fix
        .get("losses")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();

    let Ok(backend) = backend_for(spec) else {
        eprintln!("skipping: backend {spec} not compiled in");
        return;
    };
    let engine = GradEngine::new("artifacts", model, backend.as_ref()).unwrap();
    let man = engine.manifest().clone();

    // initial params from the fixture npz (exact same floats as python)
    let params_npz = read_npz(format!("artifacts/fixtures/{fixture}.params.npz")).unwrap();
    let pmap: std::collections::HashMap<_, _> = params_npz.into_iter().collect();
    let mut params: Vec<Tensor> = man
        .params
        .iter()
        .map(|p| {
            let (shape, data) = pmap[&p.name].as_f32().unwrap();
            assert_eq!(shape, p.shape.as_slice(), "{}", p.name);
            Tensor::from_vec(shape, data.to_vec())
        })
        .collect();

    let batches_npz = read_npz(format!("artifacts/fixtures/{fixture}.batches.npz")).unwrap();
    let bmap: std::collections::HashMap<_, _> = batches_npz.into_iter().collect();

    let mut opt = AdamK::new(
        "adam",
        man.params.clone(),
        vec![KMode::None; man.n_params()],
        hypers,
    );

    for t in 1..=steps {
        let batch: Vec<BatchData> = man
            .batch
            .iter()
            .map(|b| {
                let arr = &bmap[&format!("{}{}", b.name, t - 1)];
                match b.dtype.as_str() {
                    "s32" => BatchData::I32(arr.as_i32().unwrap().1.to_vec()),
                    _ => BatchData::F32(arr.as_f32().unwrap().1.to_vec()),
                }
            })
            .collect();
        let (loss, mut grads) = engine.step(&params, &batch).unwrap();
        let want = expected[t - 1] as f32;
        assert!(
            (loss - want).abs() <= rtol * want.abs() + 1e-4,
            "{model} step {t}: rust loss {loss} vs python {want}"
        );
        clip_global_norm(&mut grads, hypers.clip_norm);
        opt.step(&mut params, &grads, t, lr);
    }

    // final parameter norm must match the python reference
    let l2: f64 = params
        .iter()
        .map(|p| p.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt();
    let want_l2 = fix.get("final_param_l2").unwrap().as_f64().unwrap();
    assert!(
        (l2 - want_l2).abs() / want_l2 < 1e-3,
        "{model}: final |params| {l2} vs python {want_l2}"
    );
}

#[test]
fn linear2_replay_matches_python() {
    if !fixture_available("linear2_v64") {
        eprintln!("skipping: fixtures not built (run `make artifacts`)");
        return;
    }
    replay("linear2_v64", 2e-4);
}

#[test]
fn gpt_nano_replay_matches_python() {
    if !fixture_available("gpt_nano") {
        eprintln!("skipping: fixtures not built (run `make artifacts`)");
        return;
    }
    replay("gpt_nano", 5e-4);
}

/// The native interpreter (f64 compute path) against the JAX ground
/// truth: `python/compile/models/native_mlp.py` mirrors the builtin
/// `mlp_tiny` family — same param names/shapes/init floats, same
/// per-token mean CE — so per-step losses must agree to f32 round-off.
/// This closes the fixture-parity carry-over: the interpreter is pinned
/// to an external reference, not just to finite differences.
#[test]
fn native_mlp_replay_matches_python() {
    if !fixture_available("native_mlp") {
        eprintln!("skipping: fixtures not built (run `make fixtures`)");
        return;
    }
    replay_on(&BackendSpec::native(), "mlp_tiny", "native_mlp", 5e-4);
}
