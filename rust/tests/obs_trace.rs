//! Flight-recorder acceptance tests (DESIGN.md §15, ISSUE 7).
//!
//! Three contracts: the span rings lose nothing under concurrent
//! multi-worker emission (and drop — never block — at overflow), a trace
//! torn mid-line by a crash still parses/exports/reports under
//! `Tolerance::TornTail`, and tracing is identity-neutral — a traced
//! sweep (with live SNR telemetry) produces bit-identical fingerprints to
//! the same sweep untraced.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use slimadam::coordinator::{SweepScheduler, TrainConfig};
use slimadam::obs::{self, telemetry, Span, SpanKind, SpanRing};
use slimadam::proptest::{check, prop_assert};
use slimadam::runstore::reader::{scan_jsonl, Tolerance};
use slimadam::runtime::backend::BackendSpec;

/// Tracing state (enabled flag, flusher, rings) is process-global, and the
/// test harness runs `#[test]`s on parallel threads — every test that
/// starts/stops tracing or asserts on the disabled path serializes here.
static OBS: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "slimadam_obs_trace_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn span(i: u64) -> Span {
    Span {
        kind: SpanKind::Step,
        start_ns: i,
        dur_ns: 0,
        label: obs::NO_LABEL,
        args: [i, 0, 0, 0],
    }
}

/// SPSC rings under production topology — one producer thread per ring,
/// one consumer draining all rings concurrently: every span emitted below
/// ring capacity is delivered exactly once, in order, with zero drops.
#[test]
fn concurrent_emission_loses_nothing_below_capacity() {
    const CAP: usize = 512;
    check(8, |g| {
        let workers = g.usize(2, 6);
        let per_worker = g.usize(100, CAP);
        let rings: Vec<Arc<SpanRing>> = (0..workers)
            .map(|w| Arc::new(SpanRing::new(w as u64 + 1, CAP)))
            .collect();
        let done = AtomicBool::new(false);

        let drained: Vec<Vec<Span>> = std::thread::scope(|s| {
            let producers: Vec<_> = rings
                .iter()
                .map(|r| {
                    s.spawn(move || {
                        for i in 0..per_worker as u64 {
                            r.push(span(i));
                        }
                    })
                })
                .collect();
            let consumer = s.spawn(|| {
                let mut out: Vec<Vec<Span>> = vec![Vec::new(); workers];
                loop {
                    let finished = done.load(Ordering::Acquire);
                    for (r, sink) in rings.iter().zip(out.iter_mut()) {
                        r.drain(sink);
                    }
                    if finished && rings.iter().all(|r| r.is_empty()) {
                        return out;
                    }
                    std::thread::yield_now();
                }
            });
            for p in producers {
                p.join().unwrap();
            }
            done.store(true, Ordering::Release);
            consumer.join().unwrap()
        });

        for (w, got) in drained.iter().enumerate() {
            prop_assert(
                got.len() == per_worker,
                format!("worker {w}: drained {} of {per_worker}", got.len()),
            )?;
            for (i, s) in got.iter().enumerate() {
                prop_assert(
                    s.args[0] == i as u64,
                    format!("worker {w}: span {i} out of order ({})", s.args[0]),
                )?;
            }
        }
        prop_assert(
            rings.iter().all(|r| r.dropped() == 0),
            "no drops below capacity".to_string(),
        )?;
        Ok(())
    });
}

/// Overflow contract at the integration level: a full ring rejects new
/// spans (FIFO — the oldest survive) and counts every rejection, so a
/// saturated trace is detectable from the footer's drop total.
#[test]
fn overflow_drops_new_spans_and_counts_them() {
    let r = SpanRing::new(7, 16);
    for i in 0..40 {
        r.push(span(i));
    }
    assert_eq!(r.dropped(), 24);
    let mut out = Vec::new();
    assert_eq!(r.drain(&mut out), 16);
    assert_eq!(out[0].args[0], 0, "oldest span survives overflow");
    assert_eq!(out[15].args[0], 15);
    assert!(r.push(span(99)), "drained ring accepts pushes again");
}

/// A trace torn mid-line (SIGKILL during a flush) still parses under
/// `TornTail`, exports to Chrome format, and feeds `obs report`.
#[test]
fn torn_tail_trace_parses_exports_and_reports() {
    let _g = lock();
    let dir = tmp("torn");
    obs::start_tracing(&dir).unwrap();
    let label = obs::intern("torn-test");
    for i in 0..64u64 {
        obs::emit_instant(SpanKind::Step, label, [i, 0, 0, 0]);
    }
    let written = obs::stop_tracing().unwrap();
    assert!(written >= 64, "flushed {written} < 64 spans");

    // simulate the kill: append an unterminated half-row
    let path = dir.join(format!("trace-{}.jsonl", std::process::id()));
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(b"{\"kind\":\"step\",\"ts\":12").unwrap();
    drop(f);

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(scan_jsonl(&text, Tolerance::Strict, |_, _| Ok(())).is_err());
    let stats = scan_jsonl(&text, Tolerance::TornTail, |_, _| Ok(())).unwrap();
    assert_eq!(stats.torn, 1);
    assert!(stats.rows >= 65, "spans + footer, rows {}", stats.rows);

    let out = dir.join("trace.chrome.json");
    let export = obs::chrome::export_dir(&dir, &out).unwrap();
    assert_eq!(export.torn, 1);
    assert!(export.events >= 64, "exported {} events", export.events);
    let chrome = slimadam::json::Value::parse(&std::fs::read_to_string(&out).unwrap())
        .unwrap();
    assert!(!chrome.get("traceEvents").unwrap().as_arr().unwrap().is_empty());

    let report = obs::report::build(&dir).unwrap();
    assert!(report.contains("step"), "{report}");
    assert!(report.contains("torn tail"), "{report}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance gate: fingerprints of a traced sweep — with SNR
/// telemetry streaming — are bit-identical to the untraced sweep, and the
/// trace itself carries step + snr rows. Tracing observes, never perturbs.
#[test]
fn tracing_is_identity_neutral() {
    let _g = lock();
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [1e-3, 2e-3] {
            let mut cfg = TrainConfig::lm("mlp_tiny", opt, lr, 20);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }

    let baseline = SweepScheduler::new(2).quiet().run(&configs).unwrap();
    assert!(baseline.iter().all(|s| s.metrics.is_none()),
        "untraced rows must carry no metrics block");

    let dir = tmp("identity");
    telemetry::set_snr_every(Some(5));
    obs::start_tracing(&dir).unwrap();
    let traced = SweepScheduler::new(2).quiet().run(&configs).unwrap();
    let written = obs::stop_tracing().unwrap();
    telemetry::set_snr_every(None);
    assert!(written > 0, "traced sweep must emit spans");
    assert!(traced.iter().all(|s| s.metrics.is_some()),
        "traced rows carry the registry snapshot");

    assert_eq!(baseline.len(), traced.len());
    for (a, b) in baseline.iter().zip(&traced) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "tracing changed the identity of {}",
            a.label
        );
    }

    let path = dir.join(format!("trace-{}.jsonl", std::process::id()));
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"kind\":\"step\""), "trace carries step spans");
    assert!(text.contains("\"kind\":\"snr\""), "telemetry rows in the stream");
    std::fs::remove_dir_all(&dir).ok();
}

/// Disabled path: no clock reads, no spans, stop without start is a no-op.
#[test]
fn disabled_recorder_is_inert() {
    let _g = lock();
    assert!(!obs::enabled());
    assert_eq!(obs::clock(), 0, "clock() must not read time when disabled");
    obs::emit_instant(SpanKind::Step, obs::NO_LABEL, [1, 2, 3, 4]);
    obs::emit_since(SpanKind::Eval, obs::NO_LABEL, 0, [0; 4]);
    assert_eq!(obs::stop_tracing().unwrap(), 0);
    assert!(!telemetry::active(0), "telemetry gates on enabled() first");
}
