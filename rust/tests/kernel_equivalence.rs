//! Kernel-equivalence harness (ISSUE 6, DESIGN.md §14): every SIMD-lane
//! kernel in the native interpreter is driven against its scalar-order
//! reference over random shapes, lane counts and worker counts.
//!
//! The contract under test, per §14:
//!
//! * **Order-preserving kernels** (`matvec_t_acc_l`, `outer_acc_l`, the
//!   fused AdamW update) are bit-identical in both kernel modes and for
//!   every worker count — they never reassociate a reduction.
//! * **Reassociating kernels** (`matvec_l`, `softmax_ce_l`, `rms_fwd_l`,
//!   `rms_bwd_l`, `clip_global_norm_l`) reduce with a width-4 tree in
//!   Simd mode and must match the scalar-order reference within the
//!   documented bound `|Δ| ≤ n·ε·Σ|terms|` (n summands, machine ε, sum
//!   of absolute partial terms) — the standard worst-case bound for
//!   reassociated floating-point summation.
//! * **Lane invariance**: a lane-stacked evaluation at `l` lanes is
//!   bit-identical, lane by lane, to `l` independent evaluations at
//!   `l = 1` — the per-lane FP sequence depends only on the logical
//!   shape (this is what makes `run_batch ≡ run` hold).
//! * **Worker invariance**: intra-op parallel kernels produce bitwise
//!   identical results at 1, 2 and 8 workers.
//!
//! The end-to-end layer runs every native model × ruleset through the
//! fused train step at batch 1/2/4/8 (bit-identity) and compares Simd
//! vs ScalarRef whole-step outputs within the f32 state tolerance.

use slimadam::json::Value;
use slimadam::optim::adamk::{v_len, AdamK};
use slimadam::optim::{Hypers, KMode, Optimizer};
use slimadam::proptest::{check, prop_assert};
use slimadam::rng::Rng;
use slimadam::runtime::manifest::ParamInfo;
use slimadam::runtime::backend::native::{self, KernelMode};
use slimadam::runtime::backend::{backend_for, Backend, BackendSpec, Executable};
use slimadam::runtime::literal::{
    f32_literal, i32_literal, literal_to_tensor, scalar_f32, scalar_value,
    tensor_to_literal,
};
use slimadam::runtime::Manifest;
use slimadam::tensor::{Init, Tensor};

/// Restores the thread's kernel mode (and the global intra-op worker
/// count) when a test body exits, pass or fail.
struct ModeGuard;

impl Drop for ModeGuard {
    fn drop(&mut self) {
        native::set_kernel_mode(KernelMode::Simd);
        slimadam::pool::set_intraop_workers(1);
    }
}

/// Documented reassociation bound: `n·ε·Σ|terms|` plus a denormal floor.
fn tree_bound(n: usize, abs_sum: f64) -> f64 {
    n as f64 * f64::EPSILON * abs_sum + 1e-300
}

// ---------------------------------------------------------------------------
// Reassociating kernels vs. their scalar-order oracles
// ---------------------------------------------------------------------------

#[test]
fn matvec_simd_matches_scalar_reference_within_bound() {
    let _g = ModeGuard;
    check(60, |g| {
        let rows = g.usize(1, 24);
        let cols = g.usize(1, 96);
        let l = *g.choice(&[1usize, 2, 3, 4, 8]);
        let w = g.vec_normal_f64(rows * cols * l, 1.0);
        let v = g.vec_normal_f64(cols * l, 1.0);
        let mut simd = vec![0.0f64; rows * l];
        let mut scal = vec![0.0f64; rows * l];
        native::set_kernel_mode(KernelMode::Simd);
        native::matvec_l(&w, rows, cols, &v, &mut simd, l);
        native::matvec_ref_l(&w, rows, cols, &v, &mut scal, l);
        for r in 0..rows {
            for b in 0..l {
                let abs_sum: f64 = (0..cols)
                    .map(|c| (w[(r * cols + c) * l + b] * v[c * l + b]).abs())
                    .sum();
                let d = (simd[r * l + b] - scal[r * l + b]).abs();
                prop_assert(
                    d <= tree_bound(cols, abs_sum),
                    format!(
                        "matvec ({rows}x{cols}, l={l}) row {r} lane {b}: \
                         |Δ|={d:e} exceeds bound"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn matvec_lanes_are_bit_identical_to_lane1_runs() {
    let _g = ModeGuard;
    check(40, |g| {
        let rows = g.usize(1, 16);
        let cols = g.usize(1, 64);
        let l = *g.choice(&[2usize, 3, 4, 8]);
        // l independent jobs, then the same jobs lane-stacked
        let jobs_w: Vec<Vec<f64>> =
            (0..l).map(|_| g.vec_normal_f64(rows * cols, 1.0)).collect();
        let jobs_v: Vec<Vec<f64>> =
            (0..l).map(|_| g.vec_normal_f64(cols, 1.0)).collect();
        let mut w_l = vec![0.0f64; rows * cols * l];
        let mut v_l = vec![0.0f64; cols * l];
        for b in 0..l {
            for j in 0..rows * cols {
                w_l[j * l + b] = jobs_w[b][j];
            }
            for j in 0..cols {
                v_l[j * l + b] = jobs_v[b][j];
            }
        }
        native::set_kernel_mode(KernelMode::Simd);
        let mut out_l = vec![0.0f64; rows * l];
        native::matvec_l(&w_l, rows, cols, &v_l, &mut out_l, l);
        for b in 0..l {
            let mut out1 = vec![0.0f64; rows];
            native::matvec_l(&jobs_w[b], rows, cols, &jobs_v[b], &mut out1, 1);
            for r in 0..rows {
                prop_assert(
                    out_l[r * l + b].to_bits() == out1[r].to_bits(),
                    format!("lane {b} row {r}: l={l} stack not bit-identical to l=1"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn softmax_ce_simd_matches_scalar_reference() {
    let _g = ModeGuard;
    check(60, |g| {
        let v = g.usize(2, 192);
        let l = *g.choice(&[1usize, 2, 4]);
        let logits = g.vec_normal_f64(v * l, 3.0);
        let ys: Vec<usize> = (0..l).map(|_| g.usize(0, v - 1)).collect();
        let scale = 0.125f64;
        let run = |mode: KernelMode| {
            native::set_kernel_mode(mode);
            let mut d = vec![0.0f64; v * l];
            let mut maxs = vec![0.0f64; l];
            let mut zs = vec![0.0f64; l];
            let mut losses = vec![0.0f64; l];
            match mode {
                KernelMode::Simd => native::softmax_ce_l(
                    &logits, &ys, scale, &mut d, &mut maxs, &mut zs, &mut losses, l,
                ),
                KernelMode::ScalarRef => native::softmax_ce_ref_l(
                    &logits, &ys, scale, &mut d, &mut maxs, &mut zs, &mut losses, l,
                ),
            }
            (d, losses)
        };
        let (d_simd, loss_simd) = run(KernelMode::Simd);
        let (d_scal, loss_scal) = run(KernelMode::ScalarRef);
        // only the normalizer Z reassociates: relative v·ε on p and on
        // each dlogit, absolute v·ε on -ln p
        let rtol = 8.0 * v as f64 * f64::EPSILON;
        for b in 0..l {
            prop_assert(
                (loss_simd[b] - loss_scal[b]).abs() <= rtol * (1.0 + loss_scal[b].abs()),
                format!("softmax loss lane {b} (v={v}, l={l})"),
            )?;
        }
        for (i, (a, r)) in d_simd.iter().zip(&d_scal).enumerate() {
            prop_assert(
                (a - r).abs() <= rtol * (r.abs() + scale),
                format!("softmax dlogits[{i}] (v={v}, l={l}): {a} vs {r}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn rms_kernels_match_scalar_reference() {
    let _g = ModeGuard;
    check(60, |g| {
        let dim = g.usize(2, 160);
        let l = *g.choice(&[1usize, 2, 4]);
        let x = g.vec_normal_f64(dim * l, 1.0);
        let gw = g.vec_normal_f64(dim * l, 0.5);
        let dy = g.vec_normal_f64(dim * l, 1.0);
        let rtol = 8.0 * dim as f64 * f64::EPSILON;

        // forward: rs reassociates, out is rs-relative
        native::set_kernel_mode(KernelMode::Simd);
        let mut out_s = vec![0.0f64; dim * l];
        let mut rs_s = vec![0.0f64; l];
        native::rms_fwd_l(&x, &gw, &mut out_s, &mut rs_s, l);
        let mut out_r = vec![0.0f64; dim * l];
        let mut rs_r = vec![0.0f64; l];
        native::rms_fwd_ref_l(&x, &gw, &mut out_r, &mut rs_r, l);
        for b in 0..l {
            prop_assert(
                (rs_s[b] - rs_r[b]).abs() <= rtol * rs_r[b],
                format!("rms fwd rs lane {b} (dim={dim})"),
            )?;
        }
        for (i, (a, r)) in out_s.iter().zip(&out_r).enumerate() {
            prop_assert(
                (a - r).abs() <= rtol * (r.abs() + 1.0),
                format!("rms fwd out[{i}] (dim={dim}, l={l})"),
            )?;
        }

        // backward against the same (reference) rs: dg is elementwise
        // and bit-exact, dx carries the reassociated Σ dy·g·x
        let run_bwd = |mode: KernelMode| {
            native::set_kernel_mode(mode);
            let mut dx = vec![0.0f64; dim * l];
            let mut dg = vec![0.0f64; dim * l];
            let mut dots = vec![0.0f64; l];
            match mode {
                KernelMode::Simd => {
                    native::rms_bwd_l(&x, &gw, &rs_r, &dy, &mut dx, &mut dg, &mut dots, l)
                }
                KernelMode::ScalarRef => native::rms_bwd_ref_l(
                    &x, &gw, &rs_r, &dy, &mut dx, &mut dg, &mut dots, l,
                ),
            }
            (dx, dg)
        };
        let (dx_s, dg_s) = run_bwd(KernelMode::Simd);
        let (dx_r, dg_r) = run_bwd(KernelMode::ScalarRef);
        for (i, (a, r)) in dg_s.iter().zip(&dg_r).enumerate() {
            prop_assert(
                a.to_bits() == r.to_bits(),
                format!("rms bwd dg[{i}] must be bit-exact (elementwise sweep)"),
            )?;
        }
        for (i, (a, r)) in dx_s.iter().zip(&dx_r).enumerate() {
            prop_assert(
                (a - r).abs() <= rtol * (r.abs() + 1.0),
                format!("rms bwd dx[{i}] (dim={dim}, l={l})"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Order-preserving kernels: bit-identity across modes
// ---------------------------------------------------------------------------

#[test]
fn order_preserving_kernels_are_bit_identical_across_modes() {
    let _g = ModeGuard;
    check(40, |g| {
        let rows = g.usize(1, 20);
        let cols = g.usize(1, 40);
        let l = *g.choice(&[1usize, 2, 4]);
        let w = g.vec_normal_f64(rows * cols * l, 1.0);
        let v = g.vec_normal_f64(rows * l, 1.0);
        let u = g.vec_normal_f64(cols * l, 1.0);
        let run = |mode: KernelMode| {
            native::set_kernel_mode(mode);
            let mut t_out = vec![0.0f64; cols * l];
            native::matvec_t_acc_l(&w, rows, cols, &v, &mut t_out, l);
            let mut dw = vec![0.0f64; rows * cols * l];
            native::outer_acc_l(&mut dw, rows, cols, &v, &u, l);
            (t_out, dw)
        };
        let (t_s, dw_s) = run(KernelMode::Simd);
        let (t_r, dw_r) = run(KernelMode::ScalarRef);
        for (i, (a, r)) in t_s.iter().zip(&t_r).enumerate() {
            prop_assert(
                a.to_bits() == r.to_bits(),
                format!("matvec_t_acc[{i}] not bit-identical across modes"),
            )?;
        }
        for (i, (a, r)) in dw_s.iter().zip(&dw_r).enumerate() {
            prop_assert(
                a.to_bits() == r.to_bits(),
                format!("outer_acc[{i}] not bit-identical across modes"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Global-norm clip: tolerance vs. reference, bitwise worker invariance
// ---------------------------------------------------------------------------

#[test]
fn clip_matches_reference_and_is_bitwise_worker_invariant() {
    let _g = ModeGuard;
    check(20, |g| {
        let l = *g.choice(&[1usize, 2, 4]);
        let n_tensors = g.usize(1, 4);
        // spans multiple 8192-element intra-op chunks on at least some cases
        let grads: Vec<Vec<f32>> = (0..n_tensors)
            .map(|_| {
                let numel = g.usize(1, 20_000);
                g.vec_normal(numel * l, 1.0)
            })
            .collect();
        let total: usize = grads.iter().map(|t| t.len() / l).sum();
        // small max_norm so the rescale path actually runs
        let max_norm = 0.5;

        native::set_kernel_mode(KernelMode::ScalarRef);
        let mut g_ref = grads.clone();
        let n_ref = native::clip_global_norm_ref_l(&mut g_ref, max_norm, l);

        native::set_kernel_mode(KernelMode::Simd);
        let mut runs = Vec::new();
        for workers in [1usize, 2, 8] {
            slimadam::pool::set_intraop_workers(workers);
            let mut g_w = grads.clone();
            let n_w = native::clip_global_norm_l(&mut g_w, max_norm, l);
            runs.push((workers, g_w, n_w));
        }
        slimadam::pool::set_intraop_workers(1);

        // all worker counts bitwise identical
        let (_, g1, n1) = &runs[0];
        for (workers, g_w, n_w) in &runs[1..] {
            for (b, (a, r)) in n_w.iter().zip(n1).enumerate() {
                prop_assert(
                    a.to_bits() == r.to_bits(),
                    format!("clip norm lane {b} differs at {workers} workers"),
                )?;
            }
            for (ti, (ta, tr)) in g_w.iter().zip(g1).enumerate() {
                for (i, (a, r)) in ta.iter().zip(tr).enumerate() {
                    prop_assert(
                        a.to_bits() == r.to_bits(),
                        format!("clip grads[{ti}][{i}] differs at {workers} workers"),
                    )?;
                }
            }
        }

        // vs. the scalar-order reference: squared-sum reassociation bound
        for (b, (a, r)) in n1.iter().zip(&n_ref).enumerate() {
            let bound = tree_bound(total, r * r).sqrt().max(1e-12 * r);
            prop_assert(
                (a - r).abs() <= bound + 1e-12,
                format!("clip norm lane {b}: {a} vs ref {r}"),
            )?;
        }
        for (ti, (ta, tr)) in g1.iter().zip(&g_ref).enumerate() {
            for (i, (a, r)) in ta.iter().zip(tr).enumerate() {
                prop_assert(
                    (a - r).abs() <= 1e-6 + 1e-5 * r.abs(),
                    format!("clip grads[{ti}][{i}]: {a} vs ref {r}"),
                )?;
            }
        }
        Ok(())
    });
}

/// Regression for the clip guard (optim::clip_global_norm mirror): an
/// all-zero gradient lane passes through untouched (no 0/0 NaN), and a
/// lane whose norm is non-finite is clipped to zero — in both kernel
/// modes, bit-identically.
#[test]
fn clip_zeroes_nonfinite_lanes_and_passes_zero_gradients() {
    let _g = ModeGuard;
    let l = 2usize;
    // lane 0: norm 5.02 (> max_norm, rescaled); lane 1: carries a NaN
    let mut grads = vec![vec![0.0f32; 3 * l]];
    for (j, v) in [3.0f32, 4.0, 0.5].iter().enumerate() {
        grads[0][j * l] = *v;
        grads[0][j * l + 1] = if j == 1 { f32::NAN } else { 1.0 };
    }
    let run = |mode: KernelMode, grads: &[Vec<f32>]| {
        native::set_kernel_mode(mode);
        let mut g = grads.to_vec();
        let norms = match mode {
            KernelMode::Simd => native::clip_global_norm_l(&mut g, 1.0, l),
            KernelMode::ScalarRef => native::clip_global_norm_ref_l(&mut g, 1.0, l),
        };
        (g, norms)
    };
    for mode in [KernelMode::Simd, KernelMode::ScalarRef] {
        let (g, norms) = run(mode, &grads);
        assert!(norms[0].is_finite() && norms[0] > 1.0, "{mode:?}: {norms:?}");
        assert!(!norms[1].is_finite(), "{mode:?}: {norms:?}");
        for j in 0..3 {
            let a = g[0][j * l];
            assert!(
                a.is_finite() && a.abs() < grads[0][j * l].abs(),
                "{mode:?}: lane 0 elem {j} not rescaled finitely: {a}"
            );
            assert_eq!(
                g[0][j * l + 1].to_bits(),
                0.0f32.to_bits(),
                "{mode:?}: non-finite lane must clip to zero (elem {j})"
            );
        }
    }

    // all-zero gradients: norm 0, grads pass through bit-identically
    let zeros = vec![vec![0.0f32; 4 * l]];
    for mode in [KernelMode::Simd, KernelMode::ScalarRef] {
        let (g, norms) = run(mode, &zeros);
        assert_eq!(norms, vec![0.0f64; l], "{mode:?}");
        assert!(
            g[0].iter().all(|x| x.to_bits() == 0.0f32.to_bits()),
            "{mode:?}: zero grads must pass through"
        );
    }
}

// ---------------------------------------------------------------------------
// Fused AdamW update: bitwise across modes AND worker counts, for every
// model family × ruleset (the k_modes geometry differs per pair)
// ---------------------------------------------------------------------------

#[test]
fn fused_update_is_bitwise_invariant_for_every_model_and_ruleset() {
    let _g = ModeGuard;
    for model in native::MODELS {
        for ruleset in native::RULESETS {
            let art = native::artifact(&format!("{model}.train.{ruleset}")).unwrap();
            let man = &art.manifest;
            let k_modes = man.k_modes.as_ref().unwrap();
            let v_shapes = man.v_shapes.as_ref().unwrap();
            let hypers = man.hypers.unwrap_or_default();
            let l = 2usize;
            let mut rng = Rng::new(0xF05E);
            let mut draw = |n: usize| -> Vec<f32> {
                (0..n).map(|_| (rng.normal() * 0.1) as f32).collect()
            };
            let w0: Vec<Vec<f32>> =
                man.params.iter().map(|p| draw(p.numel() * l)).collect();
            let m0: Vec<Vec<f32>> =
                man.params.iter().map(|p| draw(p.numel() * l)).collect();
            let v0: Vec<Vec<f32>> = v_shapes
                .iter()
                .map(|vs| {
                    draw(vs.iter().product::<usize>() * l)
                        .iter()
                        .map(|x| x.abs())
                        .collect()
                })
                .collect();
            let g0: Vec<Vec<f32>> =
                man.params.iter().map(|p| draw(p.numel() * l)).collect();

            let run = |mode: KernelMode, workers: usize| {
                native::set_kernel_mode(mode);
                slimadam::pool::set_intraop_workers(workers);
                let (mut w, mut m, mut v) = (w0.clone(), m0.clone(), v0.clone());
                native::fused_update_l(
                    man,
                    k_modes,
                    &hypers,
                    &mut w,
                    &mut m,
                    &mut v,
                    &g0,
                    &[3, 7],
                    &[1e-3, 2e-3],
                    l,
                );
                (w, m, v)
            };
            let base = run(KernelMode::ScalarRef, 1);
            for (mode, workers) in [
                (KernelMode::Simd, 1),
                (KernelMode::Simd, 2),
                (KernelMode::Simd, 8),
            ] {
                let got = run(mode, workers);
                for (which, (state, want)) in [
                    (&got.0, &base.0),
                    (&got.1, &base.1),
                    (&got.2, &base.2),
                ]
                .into_iter()
                .enumerate()
                {
                    for (ti, (a, r)) in state.iter().zip(want.iter()).enumerate() {
                        for (i, (x, y)) in a.iter().zip(r).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{model}×{ruleset}: fused update state \
                                 {which} tensor {ti} elem {i} differs \
                                 ({mode:?}, {workers} workers)"
                            );
                        }
                    }
                }
            }
            slimadam::pool::set_intraop_workers(1);
        }
    }
}

/// The bake-off lane kernels (Lion, SGDM, SM3, Adafactor, rank-4
/// factored V) are scalar-order in both kernel modes and distribute
/// whole tensors across intra-op workers — so, like the fused AdamW
/// update, every state output must be bit-identical across ScalarRef /
/// Simd and 1 / 2 / 8 workers on every native model.
#[test]
fn fused_optimizer_kernels_are_bitwise_invariant_across_modes_and_workers() {
    let _g = ModeGuard;
    for model in native::MODELS {
        for token in native::OPTIMIZERS {
            let art = native::artifact(&format!("{model}.train.{token}")).unwrap();
            let man = &art.manifest;
            let k_modes = man.k_modes.as_ref().unwrap();
            let v_shapes = man.v_shapes.as_ref().unwrap();
            let hypers = man.hypers.unwrap_or_default();
            let l = 2usize;
            let mut rng = Rng::new(0xBA5E);
            let mut draw = |n: usize| -> Vec<f32> {
                (0..n).map(|_| (rng.normal() * 0.1) as f32).collect()
            };
            let w0: Vec<Vec<f32>> =
                man.params.iter().map(|p| draw(p.numel() * l)).collect();
            let m0: Vec<Vec<f32>> = (0..man.params.len())
                .map(|i| draw(man.m_shape(i).iter().product::<usize>() * l))
                .collect();
            let v0: Vec<Vec<f32>> = v_shapes
                .iter()
                .map(|vs| {
                    draw(vs.iter().product::<usize>() * l)
                        .iter()
                        .map(|x| x.abs())
                        .collect()
                })
                .collect();
            let g0: Vec<Vec<f32>> =
                man.params.iter().map(|p| draw(p.numel() * l)).collect();

            let run = |mode: KernelMode, workers: usize| {
                native::set_kernel_mode(mode);
                slimadam::pool::set_intraop_workers(workers);
                let (mut w, mut m, mut v) = (w0.clone(), m0.clone(), v0.clone());
                native::fused_optim_update_l(
                    man,
                    k_modes,
                    &hypers,
                    &mut w,
                    &mut m,
                    &mut v,
                    &g0,
                    &[3, 7],
                    &[1e-3, 2e-3],
                    l,
                )
                .unwrap();
                (w, m, v)
            };
            let base = run(KernelMode::ScalarRef, 1);
            for (mode, workers) in [
                (KernelMode::Simd, 1),
                (KernelMode::Simd, 2),
                (KernelMode::Simd, 8),
            ] {
                let got = run(mode, workers);
                for (which, (state, want)) in [
                    (&got.0, &base.0),
                    (&got.1, &base.1),
                    (&got.2, &base.2),
                ]
                .into_iter()
                .enumerate()
                {
                    for (ti, (a, r)) in state.iter().zip(want.iter()).enumerate() {
                        for (i, (x, y)) in a.iter().zip(r).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{model}×{token}: fused {token} state {which} \
                                 tensor {ti} elem {i} differs \
                                 ({mode:?}, {workers} workers)"
                            );
                        }
                    }
                }
            }
            slimadam::pool::set_intraop_workers(1);
        }
    }
}

// ---------------------------------------------------------------------------
// End to end: every model × ruleset through the whole fused train step
// ---------------------------------------------------------------------------

/// One job's full train-step input list (params, m, v, batch, step, lr)
/// in manifest order, deterministically from a seed.
fn train_inputs(man: &Manifest, seed: u64) -> Vec<xla::Literal> {
    let mut rng = Rng::new(seed);
    let mut inputs = Vec::new();
    for p in &man.params {
        let t = p.init_mitchell.materialize(&p.shape, &mut rng);
        inputs.push(tensor_to_literal(&t).unwrap());
    }
    for i in 0..man.params.len() {
        // first-moment state is per-optimizer shaped (Adafactor carries
        // none), exactly like the engine's init
        let ms = man.m_shape(i).to_vec();
        let n: usize = ms.iter().product();
        let t = Tensor::from_vec(&ms, vec![0.0; n]);
        inputs.push(tensor_to_literal(&t).unwrap());
    }
    for vs in man.v_shapes.as_ref().unwrap() {
        let n: usize = vs.iter().product();
        let t = Tensor::from_vec(vs, vec![0.0; n]);
        inputs.push(tensor_to_literal(&t).unwrap());
    }
    for b in &man.batch {
        let n: usize = b.shape.iter().product();
        match b.dtype.as_str() {
            "s32" => {
                let bound = man.token_bound() as u64;
                let data: Vec<i32> =
                    (0..n).map(|_| rng.below(bound) as i32).collect();
                inputs.push(i32_literal(&data, &b.shape).unwrap());
            }
            _ => {
                let data: Vec<f32> =
                    (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                inputs.push(f32_literal(&data, &b.shape).unwrap());
            }
        }
    }
    inputs.push(scalar_f32(1.0));
    inputs.push(scalar_f32(1e-3));
    inputs
}

/// Bit pattern of a full output list: scalars (loss, grad_norm) first,
/// then every state tensor.
fn output_bits(outs: &[xla::Literal]) -> Vec<u32> {
    let mut bits = vec![
        scalar_value(&outs[0]).unwrap().to_bits(),
        scalar_value(&outs[1]).unwrap().to_bits(),
    ];
    for o in &outs[2..] {
        let t = literal_to_tensor(o).unwrap();
        bits.extend(t.data.iter().map(|x| x.to_bits()));
    }
    bits
}

#[test]
fn train_step_batches_are_bit_identical_for_every_model_and_ruleset() {
    let _g = ModeGuard;
    let backend = backend_for(&BackendSpec::native()).unwrap();
    for model in native::MODELS {
        for ruleset in native::RULESETS.iter().chain(native::OPTIMIZERS) {
            let name = format!("{model}.train.{ruleset}");
            let art = backend
                .load_artifact(std::path::Path::new("artifacts"), &name)
                .unwrap();
            let exe = backend.compile(&art).unwrap();
            let man = &art.manifest;

            let jobs: Vec<Vec<xla::Literal>> =
                (0..8).map(|j| train_inputs(man, 100 + j)).collect();
            let sequential: Vec<Vec<u32>> = jobs
                .iter()
                .map(|inp| output_bits(&exe.run(inp).unwrap()))
                .collect();

            for batch in [1usize, 2, 4, 8] {
                let mut batched = Vec::new();
                for group in jobs.chunks(batch) {
                    for outs in exe.run_batch(group).unwrap() {
                        batched.push(output_bits(&outs));
                    }
                }
                assert_eq!(
                    batched, sequential,
                    "{name}: batch {batch} not bit-identical to sequential"
                );
            }
        }
    }
}

#[test]
fn train_step_scalar_reference_agrees_within_f32_tolerance() {
    let _g = ModeGuard;
    let backend = backend_for(&BackendSpec::native()).unwrap();
    for model in native::MODELS {
        let name = format!("{model}.train.adam");
        let art = backend
            .load_artifact(std::path::Path::new("artifacts"), &name)
            .unwrap();
        let exe = backend.compile(&art).unwrap();
        let inputs = train_inputs(&art.manifest, 7);

        native::set_kernel_mode(KernelMode::Simd);
        let simd = exe.run(&inputs).unwrap();
        native::set_kernel_mode(KernelMode::ScalarRef);
        let scal = exe.run(&inputs).unwrap();
        native::set_kernel_mode(KernelMode::Simd);

        let loss_s = scalar_value(&simd[0]).unwrap();
        let loss_r = scalar_value(&scal[0]).unwrap();
        assert!(
            (loss_s - loss_r).abs() <= 1e-5 + 1e-5 * loss_r.abs(),
            "{model}: whole-step loss Simd {loss_s} vs ScalarRef {loss_r}"
        );
        for (i, (a, r)) in simd[2..].iter().zip(&scal[2..]).enumerate() {
            let ta = literal_to_tensor(a).unwrap();
            let tr = literal_to_tensor(r).unwrap();
            for (j, (x, y)) in ta.data.iter().zip(&tr.data).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-6 + 1e-4 * y.abs(),
                    "{model}: state tensor {i} elem {j}: Simd {x} vs ScalarRef {y}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Degenerate reduced-V geometry: 1×N, N×1, 1×1 and vector tensors push
// the sharing-group geometry to its edges — group size 1 (the reduced
// update degenerates to exact Adam), group count 1 (V is a single
// scalar), and row-block partitions on tiny matrices. None of these
// shapes occur in the builtin model manifests, so the model × ruleset
// sweep above can never reach them; a hand-built manifest drives them
// through the same bitwise contract, the lane contract, and a
// split-optimizer oracle.
// ---------------------------------------------------------------------------

fn degenerate_cases() -> Vec<(Vec<usize>, KMode)> {
    vec![
        (vec![1, 5], KMode::FanIn),  // one row: V collapses to a scalar
        (vec![1, 5], KMode::FanOut), // group size 1: reduced V ≡ full V
        (vec![1, 5], KMode::Both),
        (vec![5, 1], KMode::FanOut), // one column: V collapses to a scalar
        (vec![5, 1], KMode::FanIn),  // group size 1: reduced V ≡ full V
        (vec![1, 1], KMode::Both),   // scalar tensor, scalar V
        (vec![1, 1], KMode::None),
        (vec![7], KMode::FanIn), // vector: effective K degenerates to Both
        (vec![7], KMode::None),
        (vec![3, 4], KMode::Blocks(3)), // one row per block
        (vec![1, 5], KMode::Blocks(1)), // single block on a 1×N view
        (vec![4, 3], KMode::Blocks(2)),
    ]
}

/// Hand-built fused train-step manifest over the degenerate shapes
/// (`fused_update_l` reads only `params` + the k_modes argument, so the
/// batch/io sections stay empty). Weight decay alternates per tensor to
/// exercise both wd branches of the update body.
fn degenerate_manifest() -> (Manifest, Vec<KMode>) {
    let cases = degenerate_cases();
    let params: Vec<ParamInfo> = cases
        .iter()
        .enumerate()
        .map(|(i, (shape, _))| ParamInfo {
            name: format!("p{i}"),
            shape: shape.clone(),
            layer_type: "mlp_up".into(),
            depth: 0,
            init_mitchell: Init::Normal { std: 0.02 },
            init_default: Init::Normal { std: 0.02 },
            wd: i % 2 == 0,
            fan_out_axis: 0,
        })
        .collect();
    let k_modes: Vec<KMode> = cases.iter().map(|(_, k)| *k).collect();
    let v_shapes: Vec<Vec<usize>> = params
        .iter()
        .zip(&k_modes)
        .map(|(p, &k)| vec![v_len(p, k)])
        .collect();
    let man = Manifest {
        kind: "train_step".into(),
        model_name: "degenerate".into(),
        family: "test".into(),
        meta: Value::obj(),
        params,
        batch: Vec::new(),
        inputs: Vec::new(),
        outputs: Vec::new(),
        k_modes: Some(k_modes.clone()),
        v_shapes: Some(v_shapes),
        hypers: Some(Hypers::default()),
        ruleset: Some("slimadam".into()),
        optimizer: None,
        m_shapes: None,
    };
    (man, k_modes)
}

#[test]
fn degenerate_reduced_v_geometries_are_bitwise_invariant() {
    let _g = ModeGuard;
    let (man, k_modes) = degenerate_manifest();
    let hypers = man.hypers.unwrap_or_default();
    let v_shapes = man.v_shapes.clone().unwrap();
    let l = 3usize;
    let ts = [3usize, 7, 1];
    let lrs = [1e-3f32, 2e-3, 5e-4];
    let mut rng = Rng::new(0xDE6E);
    let mut draw =
        |n: usize| -> Vec<f32> { (0..n).map(|_| (rng.normal() * 0.1) as f32).collect() };
    let w0: Vec<Vec<f32>> = man.params.iter().map(|p| draw(p.numel() * l)).collect();
    let m0: Vec<Vec<f32>> = man.params.iter().map(|p| draw(p.numel() * l)).collect();
    let v0: Vec<Vec<f32>> = v_shapes
        .iter()
        .map(|vs| {
            draw(vs.iter().product::<usize>() * l)
                .iter()
                .map(|x| x.abs())
                .collect()
        })
        .collect();
    let g0: Vec<Vec<f32>> = man.params.iter().map(|p| draw(p.numel() * l)).collect();

    let run = |mode: KernelMode, workers: usize| {
        native::set_kernel_mode(mode);
        slimadam::pool::set_intraop_workers(workers);
        let (mut w, mut m, mut v) = (w0.clone(), m0.clone(), v0.clone());
        native::fused_update_l(
            &man, &k_modes, &hypers, &mut w, &mut m, &mut v, &g0, &ts, &lrs, l,
        );
        (w, m, v)
    };
    let base = run(KernelMode::ScalarRef, 1);
    for (mode, workers) in [
        (KernelMode::Simd, 1),
        (KernelMode::Simd, 2),
        (KernelMode::Simd, 8),
    ] {
        let got = run(mode, workers);
        for (which, (state, want)) in
            [(&got.0, &base.0), (&got.1, &base.1), (&got.2, &base.2)]
                .into_iter()
                .enumerate()
        {
            for (ti, (a, r)) in state.iter().zip(want.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(r).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "degenerate geometry {:?}×{:?}: state {which} elem {i} \
                         differs ({mode:?}, {workers} workers)",
                        man.params[ti].shape,
                        k_modes[ti],
                    );
                }
            }
        }
    }
    slimadam::pool::set_intraop_workers(1);

    // Lane contract on the same geometry: lane b of the l = 3 run is
    // bit-identical to an independent l = 1 run at (t_b, lr_b).
    let lane = |src: &[Vec<f32>], b: usize| -> Vec<Vec<f32>> {
        src.iter()
            .map(|t| t.iter().skip(b).step_by(l).copied().collect())
            .collect()
    };
    native::set_kernel_mode(KernelMode::Simd);
    for b in 0..l {
        let (mut w, mut m, mut v) = (lane(&w0, b), lane(&m0, b), lane(&v0, b));
        let g1 = lane(&g0, b);
        native::fused_update_l(
            &man,
            &k_modes,
            &hypers,
            &mut w,
            &mut m,
            &mut v,
            &g1,
            &[ts[b]],
            &[lrs[b]],
            1,
        );
        for (which, (state, want)) in [
            (&w, lane(&base.0, b)),
            (&m, lane(&base.1, b)),
            (&v, lane(&base.2, b)),
        ]
        .into_iter()
        .enumerate()
        {
            for (ti, (a, r)) in state.iter().zip(want.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(r).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "lane {b}: state {which} tensor {ti} elem {i} differs \
                         from the stacked run"
                    );
                }
            }
        }
    }
}

/// The fused kernel against the split `AdamK` optimizer over three
/// sequential steps from zero state on every degenerate shape. The two
/// implementations reassociate the group reductions differently (AdamK's
/// fast paths hoist per-group denominators), so agreement is within f32
/// tolerance, not bitwise.
#[test]
fn degenerate_geometries_match_split_adamk_oracle() {
    let _g = ModeGuard;
    let (man, k_modes) = degenerate_manifest();
    let hypers = man.hypers.unwrap_or_default();
    let v_shapes = man.v_shapes.clone().unwrap();
    let mut rng = Rng::new(0x0DDC);
    let mut draw =
        |n: usize| -> Vec<f32> { (0..n).map(|_| (rng.normal() * 0.1) as f32).collect() };
    let w_init: Vec<Vec<f32>> = man.params.iter().map(|p| draw(p.numel())).collect();
    let grads: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|_| man.params.iter().map(|p| draw(p.numel())).collect())
        .collect();

    native::set_kernel_mode(KernelMode::ScalarRef);
    let mut w = w_init.clone();
    let mut m: Vec<Vec<f32>> = man.params.iter().map(|p| vec![0.0; p.numel()]).collect();
    let mut v: Vec<Vec<f32>> = v_shapes
        .iter()
        .map(|vs| vec![0.0; vs.iter().product()])
        .collect();
    for (step, g) in grads.iter().enumerate() {
        native::fused_update_l(
            &man,
            &k_modes,
            &hypers,
            &mut w,
            &mut m,
            &mut v,
            g,
            &[step + 1],
            &[1e-3],
            1,
        );
    }

    let mut opt = AdamK::new("degenerate", man.params.clone(), k_modes.clone(), hypers);
    let mut params: Vec<Tensor> = man
        .params
        .iter()
        .zip(&w_init)
        .map(|(p, d)| Tensor::from_vec(&p.shape, d.clone()))
        .collect();
    for (step, g) in grads.iter().enumerate() {
        let gt: Vec<Tensor> = man
            .params
            .iter()
            .zip(g)
            .map(|(p, d)| Tensor::from_vec(&p.shape, d.clone()))
            .collect();
        opt.step(&mut params, &gt, step + 1, 1e-3);
    }

    for (ti, (fused, split)) in w.iter().zip(&params).enumerate() {
        for (i, (x, y)) in fused.iter().zip(&split.data).enumerate() {
            assert!(
                ((*x as f64) - (*y as f64)).abs() <= 1e-6 + 1e-4 * (*y as f64).abs(),
                "{:?}×{:?} tensor {ti} elem {i}: fused {x} vs split {y}",
                man.params[ti].shape,
                k_modes[ti],
            );
        }
    }
    // The reduced V storages must agree too — compare through the shared
    // broadcast expansion so group order is normalized.
    for (ti, vi) in v.iter().enumerate() {
        let full = opt.second_moment(ti).unwrap();
        let expanded =
            slimadam::optim::adamk::expand_v(&man.params[ti], k_modes[ti], vi);
        for (i, (x, y)) in expanded.iter().zip(&full.data).enumerate() {
            assert!(
                ((*x as f64) - (*y as f64)).abs() <= 1e-9 + 1e-4 * (*y as f64).abs(),
                "{:?}×{:?} V elem {i}: fused {x} vs split {y}",
                man.params[ti].shape,
                k_modes[ti],
            );
        }
    }
}
