//! Cross-module property tests: the system-level invariants DESIGN.md §8
//! commits to, run through the in-repo property harness.

use slimadam::optim::adamk::{v_len, AdamK};
use slimadam::optim::{Hypers, KMode, Optimizer};
use slimadam::proptest::{check, close, prop_assert};
use slimadam::rng::Rng;
use slimadam::runtime::manifest::ParamInfo;
use slimadam::snr::{snr_of_view, SnrAvg, SnrSummary};
use slimadam::tensor::{Init, Tensor};

fn info(name: &str, lt: &str, shape: &[usize]) -> ParamInfo {
    ParamInfo {
        name: name.into(),
        shape: shape.to_vec(),
        layer_type: lt.into(),
        depth: 0,
        init_mitchell: Init::Normal { std: 0.02 },
        init_default: Init::Normal { std: 0.02 },
        wd: true,
        fan_out_axis: 0,
    }
}

/// AdamK with K=Both on a matrix equals AdamK on the flattened vector with
/// K=Both: compression is shape-agnostic over the same group.
#[test]
fn both_mode_is_shape_agnostic() {
    check(20, |g| {
        let rows = g.usize(1, 10);
        let cols = g.usize(1, 10);
        let n = rows * cols;
        let data = g.vec_normal(n, 1.0);
        let grad = g.vec_normal(n, 1.0);
        let h = Hypers { weight_decay: 0.0, ..Default::default() };

        let mut opt_m = AdamK::new("m", vec![info("w", "mlp_up", &[rows, cols])],
                                   vec![KMode::Both], h);
        let mut pm = vec![Tensor::from_vec(&[rows, cols], data.clone())];
        opt_m.step(&mut pm, &[Tensor::from_vec(&[rows, cols], grad.clone())], 1, 1e-2);

        let mut opt_v = AdamK::new("v", vec![info("w", "mlp_up", &[n])],
                                   vec![KMode::Both], h);
        let mut pv = vec![Tensor::from_vec(&[n], data)];
        opt_v.step(&mut pv, &[Tensor::from_vec(&[n], grad)], 1, 1e-2);

        for (a, b) in pm[0].data.iter().zip(&pv[0].data) {
            prop_assert(
                close(*a as f64, *b as f64, 1e-6, 1e-7),
                format!("{a} vs {b}"),
            )?;
        }
        Ok(())
    });
}

/// Compression preserves the group mean of G²: after one step from zero
/// state, mean over each group of the full-V equals the reduced V entry.
#[test]
fn compression_preserves_group_means() {
    check(25, |g| {
        let rows = g.usize(2, 12);
        let cols = g.usize(2, 12);
        let k = *g.choice(&[KMode::FanIn, KMode::FanOut, KMode::Both]);
        let h = Hypers::default();
        let meta = info("w", "attn_q", &[rows, cols]);
        let mut opt = AdamK::new("t", vec![meta], vec![k], h);
        let grad = Tensor::from_vec(&[rows, cols], g.vec_normal(rows * cols, 1.0));
        let mut params = vec![Tensor::zeros(&[rows, cols])];
        opt.step(&mut params, std::slice::from_ref(&grad), 1, 0.0);
        let v_full = opt.second_moment(0).unwrap();
        let scale = 1.0 - h.beta2;
        // group mean of g^2 must equal broadcast V / (1-beta2)
        match k {
            KMode::FanIn => {
                for r in 0..rows {
                    let want: f64 = (0..cols)
                        .map(|c| (grad.data[r * cols + c] as f64).powi(2))
                        .sum::<f64>()
                        / cols as f64
                        * scale;
                    let got = v_full.data[r * cols] as f64;
                    prop_assert(close(got, want, 1e-4, 1e-9), format!("{got} {want}"))?;
                }
            }
            KMode::FanOut => {
                for c in 0..cols {
                    let want: f64 = (0..rows)
                        .map(|r| (grad.data[r * cols + c] as f64).powi(2))
                        .sum::<f64>()
                        / rows as f64
                        * scale;
                    let got = v_full.data[c] as f64;
                    prop_assert(close(got, want, 1e-4, 1e-9), format!("{got} {want}"))?;
                }
            }
            _ => {
                let want: f64 = grad
                    .data
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    / (rows * cols) as f64
                    * scale;
                let got = v_full.data[0] as f64;
                prop_assert(close(got, want, 1e-4, 1e-9), format!("{got} {want}"))?;
            }
        }
        Ok(())
    });
}

/// Memory monotonicity: v_len(None) >= v_len(FanIn/FanOut) >= v_len(Both).
#[test]
fn v_len_monotone_in_compression() {
    check(50, |g| {
        let rows = g.usize(1, 64);
        let cols = g.usize(1, 64);
        let meta = info("w", "attn_q", &[rows, cols]);
        let none = v_len(&meta, KMode::None);
        let fi = v_len(&meta, KMode::FanIn);
        let fo = v_len(&meta, KMode::FanOut);
        let both = v_len(&meta, KMode::Both);
        prop_assert(none >= fi && none >= fo, "row/col <= full")?;
        prop_assert(fi >= both && fo >= both, "scalar <= row/col")?;
        prop_assert(both == 1, "both is scalar")
    });
}

/// Rule-derivation monotonicity: a higher cutoff never compresses more.
#[test]
fn cutoff_monotonicity() {
    check(30, |g| {
        let n = g.usize(1, 12);
        let metas: Vec<ParamInfo> = (0..n)
            .map(|i| info(&format!("w{i}"), "mlp_up", &[8, 8]))
            .collect();
        let per_param: Vec<SnrAvg> = (0..n)
            .map(|_| SnrAvg {
                fan_out: g.f64(0.0, 4.0),
                fan_in: g.f64(0.0, 4.0),
                both: g.f64(0.0, 4.0),
                n: 3,
            })
            .collect();
        let summary = SnrSummary { per_param, metas };
        let lo = slimadam::rules::RuleSet::derive(&summary, 0.5, "lo", None);
        let hi = slimadam::rules::RuleSet::derive(&summary, 2.0, "hi", None);
        prop_assert(
            hi.rules.len() <= lo.rules.len(),
            format!("{} > {}", hi.rules.len(), lo.rules.len()),
        )
    });
}

/// SNR scale-invariance: SNR_K(c·V) == SNR_K(V) for c > 0 (it is a ratio).
#[test]
fn snr_scale_invariant() {
    check(40, |g| {
        let rows = g.usize(2, 20);
        let cols = g.usize(2, 20);
        let c = g.log_f64(1e-3, 1e3) as f32;
        let data: Vec<f32> = (0..rows * cols).map(|_| g.f32(1e-4, 1.0)).collect();
        let scaled: Vec<f32> = data.iter().map(|&x| x * c).collect();
        for k in [KMode::FanOut, KMode::FanIn, KMode::Both] {
            let a = snr_of_view(rows, cols, &data, k);
            let b = snr_of_view(rows, cols, &scaled, k);
            prop_assert(
                close(a, b, 1e-3, 1e-9),
                format!("K={k:?}: {a} vs {b} (c={c})"),
            )?;
        }
        Ok(())
    });
}

/// Zero-LR steps must leave parameters untouched for the whole family.
#[test]
fn zero_lr_is_identity() {
    let man_params = vec![info("a", "attn_q", &[6, 6]), info("b", "ln_attn", &[6])];
    let mut rng = Rng::new(5);
    let params0: Vec<Tensor> = man_params
        .iter()
        .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
        .collect();
    let grads: Vec<Tensor> = man_params
        .iter()
        .map(|p| {
            Tensor::from_vec(
                &p.shape,
                (0..p.numel()).map(|_| rng.normal() as f32).collect(),
            )
        })
        .collect();
    for k in [KMode::None, KMode::FanIn, KMode::FanOut, KMode::Both] {
        let mut opt = AdamK::new("t", man_params.clone(), vec![k, k], Hypers::default());
        let mut params = params0.clone();
        opt.step(&mut params, &grads, 1, 0.0);
        assert_eq!(params, params0, "K={k:?}");
    }
}

/// BPE: encoding never produces ids outside the vocab and decode inverts
/// encode for newline-free input.
#[test]
fn bpe_fuzz_roundtrip() {
    use slimadam::data::bpe::Bpe;
    let corpus = b"all work and no play makes jack a dull boy\n".repeat(40);
    let bpe = Bpe::train(&corpus, 300 + 17);
    check(60, |g| {
        let n = g.usize(0, 300);
        let bytes: Vec<u8> = (0..n)
            .map(|_| g.usize(0, 255) as u8)
            .map(|b| if b == b'\n' { b' ' } else { b })
            .collect();
        let toks = bpe.encode(&bytes);
        prop_assert(
            toks.iter().all(|&t| (t as usize) < bpe.vocab_size),
            "token out of vocab",
        )?;
        prop_assert(bpe.decode(&toks) == bytes, "roundtrip")
    });
}

// ---------------------------------------------------------------------------
// JSON substrate: round-trip properties shared by the DOM parser and the
// runstore streaming reader (both drive the same json::Lexer, so they
// must accept identical inputs and agree on every value).
// ---------------------------------------------------------------------------

/// Rebuild a Value from the streaming event sequence — the test-side
/// inverse of `runstore::reader::scan_value`.
fn value_from_events(src: &str) -> anyhow::Result<slimadam::json::Value> {
    use slimadam::json::{Lexer, Value};
    use slimadam::runstore::Event;

    enum Frame {
        Arr(Vec<Value>),
        Obj(std::collections::BTreeMap<String, Value>, Option<String>),
    }
    let mut stack: Vec<Frame> = Vec::new();
    let mut root: Option<Value> = None;
    let place = |stack: &mut Vec<Frame>, root: &mut Option<Value>, v: Value| {
        match stack.last_mut() {
            None => *root = Some(v),
            Some(Frame::Arr(items)) => items.push(v),
            Some(Frame::Obj(map, key)) => {
                map.insert(key.take().expect("value without key"), v);
            }
        }
    };
    let mut lex = Lexer::new(src);
    slimadam::runstore::scan_value(&mut lex, &mut |ev: Event<'_>| {
        match ev {
            Event::ObjBegin => stack.push(Frame::Obj(Default::default(), None)),
            Event::ArrBegin => stack.push(Frame::Arr(Vec::new())),
            Event::ObjEnd | Event::ArrEnd => {
                let v = match stack.pop().unwrap() {
                    Frame::Arr(items) => Value::Arr(items),
                    Frame::Obj(map, _) => Value::Obj(map),
                };
                place(&mut stack, &mut root, v);
            }
            Event::Key(k) => {
                if let Some(Frame::Obj(_, key)) = stack.last_mut() {
                    *key = Some(k.into_owned());
                }
            }
            Event::Str(s) => place(&mut stack, &mut root, Value::Str(s.into_owned())),
            Event::Num(n) => place(&mut stack, &mut root, Value::Num(n)),
            Event::Bool(b) => place(&mut stack, &mut root, Value::Bool(b)),
            Event::Null => place(&mut stack, &mut root, Value::Null),
        }
        Ok(())
    })?;
    root.ok_or_else(|| anyhow::anyhow!("no value"))
}

/// dump -> parse is the identity on arbitrary value trees (DOM path).
#[test]
fn json_dom_roundtrip() {
    use slimadam::json::Value;
    check(150, |g| {
        let v = g.json_value(3);
        let text = v.dump();
        let back = Value::parse(&text)
            .map_err(|e| format!("reparse of {text:?} failed: {e:#}"))?;
        prop_assert(back == v, format!("roundtrip mismatch on {text:?}"))
    });
}

/// The streaming reader reconstructs exactly what the DOM parser sees,
/// on both compact and pretty serializations.
#[test]
fn json_streaming_agrees_with_dom() {
    check(150, |g| {
        let v = g.json_value(3);
        for text in [v.dump(), v.dump_pretty()] {
            let streamed = value_from_events(&text)
                .map_err(|e| format!("stream of {text:?} failed: {e:#}"))?;
            prop_assert(streamed == v, format!("stream mismatch on {text:?}"))?;
        }
        Ok(())
    });
}

/// Arbitrary strings — escapes, control chars, astral plane — survive
/// dump -> parse bit-exactly through both paths.
#[test]
fn json_string_edge_cases_roundtrip() {
    use slimadam::json::Value;
    check(300, |g| {
        let s = g.json_string(24);
        let text = Value::Str(s.clone()).dump();
        let dom = Value::parse(&text)
            .map_err(|e| format!("parse of {text:?} failed: {e:#}"))?;
        prop_assert(dom == Value::Str(s.clone()), format!("dom {text:?}"))?;
        let streamed = value_from_events(&text)
            .map_err(|e| format!("stream of {text:?} failed: {e:#}"))?;
        prop_assert(streamed == Value::Str(s), format!("stream {text:?}"))
    });
}

/// Surrogate-pair escape forms decode to the same astral string the
/// raw-UTF-8 form does, and lone surrogates are rejected by both layers.
#[test]
fn json_surrogate_handling() {
    use slimadam::json::Value;
    let paired = Value::parse(r#""😀""#).unwrap();
    assert_eq!(paired.as_str().unwrap(), "😀");
    assert_eq!(value_from_events(r#""😀""#).unwrap(), paired);
    for bad in [r#""\ud83d""#, r#""\ude00""#, r#""\ud83dx""#] {
        assert!(Value::parse(bad).is_err(), "DOM must reject {bad}");
        assert!(value_from_events(bad).is_err(), "stream must reject {bad}");
    }
}

/// Strict number grammar: everything `str::parse::<f64>` would happily
/// accept but RFC 8259 forbids is rejected by both layers; valid numbers
/// round-trip through dump with full precision for integers.
#[test]
fn json_number_edge_cases() {
    use slimadam::json::Value;
    for bad in ["NaN", "Infinity", "-Infinity", "+1", "01", "1.", ".5", "1e", "1e+"] {
        assert!(Value::parse(bad).is_err(), "DOM must reject {bad}");
        assert!(value_from_events(bad).is_err(), "stream must reject {bad}");
    }
    check(200, |g| {
        let n = if g.bool() {
            g.usize(0, 1 << 50) as f64
        } else {
            g.f64(-1e12, 1e12)
        };
        let text = slimadam::json::Value::Num(n).dump();
        let dom = slimadam::json::Value::parse(&text)
            .map_err(|e| format!("parse of {text:?} failed: {e:#}"))?;
        let back = dom.as_f64().map_err(|e| format!("{e:#}"))?;
        prop_assert(
            back == n || (back - n).abs() <= 1e-9 * n.abs().max(1.0),
            format!("{n} -> {text} -> {back}"),
        )
    });
}

// ---------------------------------------------------------------------------
// Batch planner properties (DESIGN.md §12): arbitrary job lists → the
// planner's groups are a deterministic partition that never mixes shard
// keys, never exceeds the batch cap, isolates probed configs, and never
// touches the configs themselves — derived per-job seeds survive any
// grouping.
// ---------------------------------------------------------------------------

fn arbitrary_config(g: &mut slimadam::proptest::Gen) -> slimadam::coordinator::TrainConfig {
    use slimadam::coordinator::{EngineKind, TrainConfig};
    use slimadam::runtime::backend::BackendSpec;
    let model = *g.choice(&["mlp_tiny", "gpt_micro", "gpt_nano"]);
    let opt = *g.choice(&["adam", "slimadam", "sgdm"]);
    let mut cfg = TrainConfig::lm(model, opt, g.log_f64(1e-5, 1e-1), g.usize(1, 40));
    cfg.backend = if g.bool() {
        BackendSpec::native()
    } else {
        BackendSpec::pjrt()
    };
    if g.bool() {
        cfg.engine = EngineKind::Fused((*g.choice(&["adam", "slimadam"])).to_string());
    }
    cfg.warmup = g.usize(0, 10);
    cfg.accum = g.usize(1, 3);
    cfg.eval_batches = g.usize(0, 4);
    cfg.seed = g.u64();
    if g.usize(0, 5) == 0 {
        cfg.probe = Some(slimadam::snr::ProbeSchedule::default());
    }
    cfg
}

/// Groups are a partition of the input indices, each group shares one
/// feasibility key (hence one shard key), respects the batch cap, and
/// probed configs are always singletons.
#[test]
fn batch_plan_is_a_capped_partition_of_same_key_jobs() {
    use slimadam::coordinator::batch::{group_key, plan};
    use slimadam::coordinator::SweepScheduler;
    check(60, |g| {
        let n = g.usize(0, 24);
        let configs: Vec<_> = (0..n).map(|_| arbitrary_config(g)).collect();
        let indices: Vec<usize> = (0..n).collect();
        let max = g.usize(1, 8);
        let groups = plan(&configs, &indices, max);

        // partition: every index exactly once, order-preserving per group
        let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
        seen.sort_unstable();
        prop_assert(seen == indices, "groups must partition the indices")?;

        for group in &groups {
            prop_assert(!group.is_empty(), "no empty groups")?;
            prop_assert(
                group.len() <= max,
                format!("group of {} exceeds max {max}", group.len()),
            )?;
            let key0 = group_key(&configs[group[0]]);
            let shard0 = SweepScheduler::shard_key(&configs[group[0]]);
            for &i in group {
                prop_assert(
                    group_key(&configs[i]) == key0,
                    "grouped jobs must share a feasibility key",
                )?;
                prop_assert(
                    SweepScheduler::shard_key(&configs[i]) == shard0,
                    "grouped jobs must share a shard key",
                )?;
            }
            if group.len() > 1 {
                for &i in group {
                    prop_assert(
                        configs[i].probe.is_none(),
                        "probed configs must stay singletons",
                    )?;
                }
            }
        }

        // deterministic: planning again yields the same groups
        prop_assert(plan(&configs, &indices, max) == groups, "plan must be deterministic")
    });
}

/// Grouping never rewrites configs: jobs seeded with `rng::job_seed`
/// keep their derived seed no matter the batch size, so batched replicate
/// sweeps stay a pure function of grid position.
#[test]
fn batch_plan_preserves_derived_job_seeds() {
    use slimadam::coordinator::batch::plan;
    use slimadam::rng::job_seed;
    check(40, |g| {
        let n = g.usize(1, 16);
        let base_seed = g.u64();
        let mut configs: Vec<_> = (0..n).map(|_| arbitrary_config(g)).collect();
        for (i, cfg) in configs.iter_mut().enumerate() {
            cfg.seed = job_seed(base_seed, i as u64);
        }
        let indices: Vec<usize> = (0..n).collect();
        for max in [1, 2, 4, 8] {
            let groups = plan(&configs, &indices, max);
            for group in &groups {
                for &i in group {
                    prop_assert(
                        configs[i].seed == job_seed(base_seed, i as u64),
                        "planning must not rewrite per-job seeds",
                    )?;
                }
            }
        }
        Ok(())
    });
}
