//! Scheduler invariants (DESIGN.md §9): worker count must never change
//! sweep results — per-job metrics are a pure function of the config —
//! and the executable cache must make compilation per-worker-once, not
//! per-job.
//!
//! The artifact-backed test self-skips when `make artifacts` has not run
//! (same convention as the other integration suites); the scheduling
//! substrate itself is exercised unconditionally.
//!
//! Note: only one test here may touch `exec_cache`'s global counters —
//! libtest runs tests in this binary concurrently.

use slimadam::coordinator::{exec_cache, EngineKind, SweepScheduler, TrainConfig};
use slimadam::pool::{parallel_map_sharded, set_intraop_workers};
use slimadam::rng::job_seed;
use slimadam::runtime::backend::BackendSpec;

#[test]
fn sharded_pool_output_is_worker_independent() {
    let inputs: Vec<u64> = (0..64).collect();
    let run = |workers: usize| {
        parallel_map_sharded(&inputs, workers, |_, &x| x % 3, |i, &x| {
            Ok(x.wrapping_mul(31).wrapping_add(i as u64))
        })
        .unwrap()
    };
    let serial = run(1);
    for workers in [2, 4, 8] {
        assert_eq!(run(workers), serial, "workers={workers}");
    }
}

#[test]
fn job_seeds_survive_roundtrips() {
    // the derived seeds a sweep injects are pure functions of (base, index)
    let a: Vec<u64> = (0..16).map(|i| job_seed(9, i)).collect();
    let b: Vec<u64> = (0..16).map(|i| job_seed(9, i)).collect();
    assert_eq!(a, b);
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/linear2_v64.grad.hlo.txt").exists()
}

/// The acceptance test for the parallel scheduler: an 8-point LR sweep
/// at `--workers 4` produces byte-identical per-job metrics to the
/// serial run, and each distinct artifact compiles at most once per
/// worker (asserted via the cache counters).
#[test]
fn parallel_sweep_matches_serial_and_compiles_once_per_worker() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut configs = Vec::new();
    for i in 0..8 {
        let mut cfg = TrainConfig::lm("linear2_v64", "adam", 1e-3, 8);
        cfg.lr = 1e-3 * (1.0 + 0.2 * i as f64);
        cfg.eval_batches = 2;
        configs.push(cfg);
    }

    exec_cache::reset_stats();
    let serial = SweepScheduler::new(1).quiet().run(&configs).unwrap();
    let s1 = exec_cache::stats();
    assert_eq!(s1.hits + s1.misses, 8, "{s1:?}");
    assert!(s1.compiles() <= 1, "serial worker recompiled: {s1:?}");

    exec_cache::reset_stats();
    let parallel = SweepScheduler::new(4).quiet().run(&configs).unwrap();
    let s2 = exec_cache::stats();
    assert_eq!(s2.hits + s2.misses, 8, "{s2:?}");
    assert!(
        s2.compiles() <= 4,
        "one distinct artifact × 4 workers must compile ≤ 4 times: {s2:?}"
    );

    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            a.result.fingerprint(),
            b.result.fingerprint(),
            "parallel metrics diverged from serial for {}",
            a.label
        );
        assert_eq!(a.result.losses, b.result.losses, "{}", a.label);
    }
}

/// Intra-op kernel parallelism must be invisible in the results (ISSUE
/// 6, DESIGN.md §14): the SIMD clip and fused-update kernels fold
/// per-chunk partials in `(tensor, chunk)` index order whatever thread
/// computed them, so real native train runs — split and fused engines —
/// produce byte-identical fingerprints at `workers = 1 ≡ 2 ≡ 8`.
///
/// This is the regression test for the latent non-determinism risk in
/// sharded reductions: any racy fold order shows up here as a
/// fingerprint mismatch.
#[test]
fn intraop_parallel_train_steps_are_worker_count_invariant() {
    let mut configs = Vec::new();
    for (opt, lr) in [("adam", 1e-3), ("slimadam", 2e-3)] {
        let mut cfg = TrainConfig::auto("mlp_tiny", opt, lr, 10);
        cfg.backend = BackendSpec::native();
        cfg.eval_batches = 2;
        configs.push(cfg);
    }
    let mut fused = TrainConfig::auto("gpt_micro", "adam", 1e-3, 4);
    fused.backend = BackendSpec::native();
    fused.engine = EngineKind::Fused("slimadam".to_string());
    configs.push(fused);

    let run = |intraop: usize| {
        set_intraop_workers(intraop);
        let out = SweepScheduler::new(1).quiet().run(&configs).unwrap();
        set_intraop_workers(1);
        out
    };
    let base = run(1);
    assert!(base
        .iter()
        .all(|s| !s.result.losses.is_empty() && s.result.final_train_loss.is_finite()));
    for intraop in [2usize, 8] {
        let got = run(intraop);
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(
                a.result.fingerprint(),
                b.result.fingerprint(),
                "intraop={intraop} changed results for {}",
                a.label
            );
            assert_eq!(a.result.losses, b.result.losses, "{}", a.label);
            assert_eq!(
                a.result.final_train_loss.to_bits(),
                b.result.final_train_loss.to_bits(),
                "{}",
                a.label
            );
        }
    }
}
