//! Split-vs-fused engine agreement: the fused train_step (Pallas fused
//! update inlined at L2 on the PJRT path; the interpreter's fused update
//! on the native path) and the split path (grad_step + Rust AdamK)
//! implement the same mathematics. Driving both with identical seeds,
//! batches and schedules must produce matching loss trajectories — the
//! strongest end-to-end consistency check across all three layers.
//!
//! The PJRT variants need `make artifacts` and self-skip without it; the
//! native variants run unconditionally (builtin models, no files), so CI
//! always exercises the full agreement property on at least one backend.

use slimadam::data::DataSource;
use slimadam::optim::adamk::AdamK;
use slimadam::optim::{clip_global_norm, KMode, Optimizer};
use slimadam::runtime::backend::{backend_for, Backend, BackendSpec};
use slimadam::runtime::engine::{GradEngine, TrainEngine};
use slimadam::runtime::KMode as K;
use slimadam::tensor::Tensor;

fn have(name: &str) -> bool {
    std::path::Path::new(&format!("artifacts/{name}.hlo.txt")).exists()
}

fn run_agreement(
    backend: &dyn Backend,
    model: &str,
    ruleset: &str,
    modes_for: impl Fn(&slimadam::runtime::Manifest) -> Vec<KMode>,
) {
    let steps = 8;
    let lr = 1e-3f32;
    let seed = 42u64;

    // --- fused path ---
    let mut fused =
        TrainEngine::new("artifacts", model, ruleset, backend, "mitchell", seed).unwrap();
    let man = fused.manifest().clone();
    let hypers = man.hypers.unwrap();
    // family-appropriate workload: token stream for LM manifests,
    // synthetic images for the conv family
    let mut data1 = slimadam::coordinator::make_data(
        &man,
        &slimadam::coordinator::DataSpec::default_for(&man),
        99,
    )
    .unwrap();
    let mut fused_losses = Vec::new();
    let mut batches = Vec::new();
    for _ in 0..steps {
        let b = data1.next_batch();
        batches.push(b.clone());
        fused_losses.push(fused.step(&b, lr).unwrap().loss);
    }

    // --- split path with the same init (same seed => same param draw) ---
    let engine = GradEngine::new("artifacts", model, backend).unwrap();
    let gman = engine.manifest().clone();
    let mut rng = slimadam::rng::Rng::new(seed);
    let mut params: Vec<Tensor> = gman
        .params
        .iter()
        .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
        .collect();
    // modes_for sees the FUSED manifest (same params as the grad one), so
    // callers can hand the authoritative baked k_modes to the split path.
    let modes = modes_for(&man);
    let mut opt = AdamK::new("x", gman.params.clone(), modes, hypers);
    let mut split_losses = Vec::new();
    for (t, b) in batches.iter().enumerate() {
        let (loss, mut grads) = engine.step(&params, b).unwrap();
        split_losses.push(loss);
        clip_global_norm(&mut grads, hypers.clip_norm);
        opt.step(&mut params, &grads, t + 1, lr);
    }

    for (t, (f, s)) in fused_losses.iter().zip(&split_losses).enumerate() {
        assert!(
            (f - s).abs() <= 1e-3 + 2e-3 * s.abs(),
            "{model}/{ruleset} step {t}: fused {f} vs split {s}\n\
             fused: {fused_losses:?}\nsplit: {split_losses:?}"
        );
    }
}

fn pjrt_backend() -> Option<std::rc::Rc<dyn Backend>> {
    backend_for(&BackendSpec::pjrt()).ok()
}

#[test]
fn adam_engines_agree() {
    if !have("gpt_nano.train.adam") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Some(backend) = pjrt_backend() else { return };
    run_agreement(backend.as_ref(), "gpt_nano", "adam", |man| {
        vec![K::None; man.n_params()]
    });
}

#[test]
fn slimadam_engines_agree() {
    if !have("gpt_nano.train.slimadam") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Some(backend) = pjrt_backend() else { return };
    run_agreement(backend.as_ref(), "gpt_nano", "slimadam", |man| {
        slimadam::rules::RuleSet::table3_default(man).modes_for(man)
    });
}

#[test]
fn adalayer_engines_agree() {
    if !have("gpt_nano.train.adalayer") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Some(backend) = pjrt_backend() else { return };
    run_agreement(backend.as_ref(), "gpt_nano", "adalayer", |man| {
        man.params.iter().map(|_| K::Both).collect()
    });
}

/// The fused artifacts' baked k_modes must agree with the Rust presets'
/// view of the same ruleset (manifest contract check).
#[test]
fn fused_manifest_k_modes_match_rust_rules() {
    if !have("gpt_nano.train.slimadam") {
        return;
    }
    let man = slimadam::runtime::Manifest::load(
        "artifacts/gpt_nano.train.slimadam.manifest.json",
    )
    .unwrap();
    let baked = man.k_modes.clone().unwrap();
    let rules = slimadam::rules::RuleSet::table3_default(&man);
    let expect = rules.modes_for(&man);
    for ((p, b), e) in man.params.iter().zip(&baked).zip(&expect) {
        // python encodes vector "none" as none; adamk::effective_k handles
        // vector degeneration on the rust side — compare effective modes.
        let eb = slimadam::optim::adamk::effective_k(p, *b);
        let ee = slimadam::optim::adamk::effective_k(p, *e);
        assert_eq!(eb, ee, "{}", p.name);
    }
}

// ---------------------------------------------------------------------------
// Native-backend agreement and determinism (no artifacts needed)
// ---------------------------------------------------------------------------

/// Native split-vs-fused agreement for every builtin model × ruleset:
/// the interpreter's fused update must match grad_step + Rust AdamK.
#[test]
fn native_engines_agree_all_models_and_rulesets() {
    let backend = backend_for(&BackendSpec::native()).unwrap();
    for &model in slimadam::runtime::backend::native::MODELS {
        for &ruleset in slimadam::runtime::backend::native::RULESETS {
            // The split path mirrors exactly the K modes the fused
            // manifest baked — the authoritative encoding, so a change in
            // native ruleset semantics can never silently desynchronize
            // the two sides of this test.
            run_agreement(backend.as_ref(), model, ruleset, |man| {
                man.k_modes.clone().expect("fused manifest carries k_modes")
            });
        }
    }
}

/// Native-vs-stub compile paths: the same artifact name resolves on both
/// backends, and each backend rejects the other's artifact source — the
/// native interpreter refuses HLO text, the (stubbed) PJRT backend
/// refuses builtin manifests with a `--backend native` hint.
#[test]
fn native_vs_stub_compile_paths() {
    let native = backend_for(&BackendSpec::native()).unwrap();
    let art = slimadam::runtime::backend::native::artifact("gpt_micro.grad").unwrap();
    // native compiles its builtin artifact
    assert!(art.compile(native.as_ref()).is_ok());

    #[cfg(feature = "pjrt")]
    {
        let pjrt = backend_for(&BackendSpec::pjrt()).unwrap();
        // the pjrt backend must refuse a builtin (no-HLO) artifact
        let err = art.compile(pjrt.as_ref()).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
        // and with the offline stub, compiling real HLO text errors with
        // a stub pointer rather than succeeding silently
        if have("linear2_v64.grad") {
            let hlo = slimadam::runtime::Artifact::load("artifacts", "linear2_v64.grad")
                .unwrap();
            if let Err(e) = hlo.compile(pjrt.as_ref()) {
                assert!(format!("{e}").contains("stub") || format!("{e}").contains("PJRT"));
            }
        }
    }
}

/// Native-backend determinism: the same grid run with workers=1 and
/// workers=4 must produce byte-identical `RunResult::fingerprint`s —
/// worker count and scheduling order never leak into metrics.
#[test]
fn native_sweep_deterministic_across_worker_counts() {
    use slimadam::coordinator::{SweepScheduler, TrainConfig};
    let mut configs = Vec::new();
    for (i, opt) in ["adam", "slimadam"].iter().enumerate() {
        for j in 0..3 {
            let mut cfg = TrainConfig::lm("mlp_tiny", opt, 1e-3 * (1.0 + j as f64), 12);
            cfg.backend = BackendSpec::native();
            cfg.seed = (i * 3 + j) as u64;
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    let serial = SweepScheduler::new(1).quiet().run(&configs).unwrap();
    let parallel = SweepScheduler::new(4).quiet().run(&configs).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            a.result.fingerprint(),
            b.result.fingerprint(),
            "workers=4 diverged from workers=1 for {}",
            a.label
        );
        assert_eq!(a.result.losses, b.result.losses, "{}", a.label);
    }
}
