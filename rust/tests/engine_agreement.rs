//! Split-vs-fused engine agreement: the HLO fused train_step (Pallas
//! fused-update kernel inlined at L2) and the split path (HLO grad_step +
//! Rust AdamK) implement the same mathematics. Driving both with identical
//! seeds, batches and schedules must produce matching loss trajectories —
//! the strongest end-to-end consistency check across all three layers.

use slimadam::data::DataSource;
use slimadam::optim::adamk::AdamK;
use slimadam::optim::{clip_global_norm, KMode, Optimizer};
use slimadam::runtime::engine::{cpu_client, GradEngine, TrainEngine};
use slimadam::runtime::KMode as K;
use slimadam::tensor::Tensor;

fn have(name: &str) -> bool {
    std::path::Path::new(&format!("artifacts/{name}.hlo.txt")).exists()
}

fn run_agreement(model: &str, ruleset: &str, modes_for: impl Fn(&slimadam::runtime::Manifest) -> Vec<KMode>) {
    let client = cpu_client().unwrap();
    let steps = 8;
    let lr = 1e-3f32;
    let seed = 42u64;

    // --- fused path ---
    let mut fused =
        TrainEngine::new("artifacts", model, ruleset, &client, "mitchell", seed).unwrap();
    let man = fused.manifest().clone();
    let hypers = man.hypers.unwrap();
    let mut data1 = slimadam::coordinator::make_data(
        &man,
        &slimadam::coordinator::DataSpec::Markov {
            alpha: 1.07,
            coherence: 0.5,
            seed: 7,
        },
        99,
    )
    .unwrap();
    let mut fused_losses = Vec::new();
    let mut batches = Vec::new();
    for _ in 0..steps {
        let b = data1.next_batch();
        batches.push(b.clone());
        fused_losses.push(fused.step(&b, lr).unwrap().loss);
    }

    // --- split path with the same init (same seed => same param draw) ---
    let engine = GradEngine::new("artifacts", model, &client).unwrap();
    let gman = engine.manifest().clone();
    let mut rng = slimadam::rng::Rng::new(seed);
    let mut params: Vec<Tensor> = gman
        .params
        .iter()
        .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
        .collect();
    let modes = modes_for(&gman);
    let mut opt = AdamK::new("x", gman.params.clone(), modes, hypers);
    let mut split_losses = Vec::new();
    for (t, b) in batches.iter().enumerate() {
        let (loss, mut grads) = engine.step(&params, b).unwrap();
        split_losses.push(loss);
        clip_global_norm(&mut grads, hypers.clip_norm);
        opt.step(&mut params, &grads, t + 1, lr);
    }

    for (t, (f, s)) in fused_losses.iter().zip(&split_losses).enumerate() {
        assert!(
            (f - s).abs() <= 1e-3 + 2e-3 * s.abs(),
            "{model}/{ruleset} step {t}: fused {f} vs split {s}\n\
             fused: {fused_losses:?}\nsplit: {split_losses:?}"
        );
    }
}

#[test]
fn adam_engines_agree() {
    if !have("gpt_nano.train.adam") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    run_agreement("gpt_nano", "adam", |man| vec![K::None; man.n_params()]);
}

#[test]
fn slimadam_engines_agree() {
    if !have("gpt_nano.train.slimadam") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    run_agreement("gpt_nano", "slimadam", |man| {
        slimadam::rules::RuleSet::table3_default(man).modes_for(man)
    });
}

#[test]
fn adalayer_engines_agree() {
    if !have("gpt_nano.train.adalayer") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    run_agreement("gpt_nano", "adalayer", |man| {
        man.params
            .iter()
            .map(|_| K::Both)
            .collect()
    });
}

/// The fused artifacts' baked k_modes must agree with the Rust presets'
/// view of the same ruleset (manifest contract check).
#[test]
fn fused_manifest_k_modes_match_rust_rules() {
    if !have("gpt_nano.train.slimadam") {
        return;
    }
    let man = slimadam::runtime::Manifest::load(
        "artifacts/gpt_nano.train.slimadam.manifest.json",
    )
    .unwrap();
    let baked = man.k_modes.clone().unwrap();
    let rules = slimadam::rules::RuleSet::table3_default(&man);
    let expect = rules.modes_for(&man);
    for ((p, b), e) in man.params.iter().zip(&baked).zip(&expect) {
        // python encodes vector "none" as none; adamk::effective_k handles
        // vector degeneration on the rust side — compare effective modes.
        let eb = slimadam::optim::adamk::effective_k(p, *b);
        let ee = slimadam::optim::adamk::effective_k(p, *e);
        assert_eq!(eb, ee, "{}", p.name);
    }
}
