//! End-to-end smoke tests: every model family trains a few steps through
//! the full stack (artifact → backend → data pipeline → optimizer), and
//! the core paper claims hold qualitatively even at smoke scale. The
//! artifact-backed tests self-skip without `make artifacts`; the
//! native-backend tests run unconditionally (builtin models).

use slimadam::coordinator::{run_config, DataSpec, EngineKind, TrainConfig};
use slimadam::runtime::backend::BackendSpec;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/gpt_nano.grad.hlo.txt").exists()
}

#[test]
fn every_model_family_trains() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for (model, vision) in [
        ("gpt_nano", false),
        ("llama_tiny", false),
        ("vit_mini_c10", true),
        ("resnet_mini_c10", true),
        ("linear2_v256", false),
    ] {
        let mut cfg = if vision {
            TrainConfig::vision(model, "adam", 1e-3, 6)
        } else {
            TrainConfig::lm(model, "adam", 1e-3, 6)
        };
        cfg.eval_batches = 1;
        let s = run_config(&cfg).unwrap_or_else(|e| panic!("{model}: {e:#}"));
        assert!(!s.result.diverged, "{model} diverged");
        assert!(s.result.final_train_loss.is_finite(), "{model}");
    }
}

#[test]
fn every_optimizer_trains_gpt_nano() {
    if !have_artifacts() {
        return;
    }
    for opt in slimadam::optim::presets::ALL {
        let mut cfg = TrainConfig::lm("gpt_nano", opt, 3e-4, 5);
        cfg.eval_batches = 0;
        let s = run_config(&cfg).unwrap_or_else(|e| panic!("{opt}: {e:#}"));
        assert!(
            s.result.losses.iter().all(|(_, l)| l.is_finite()),
            "{opt} produced non-finite loss"
        );
    }
}

#[test]
fn slimadam_learns_like_adam_at_smoke_scale() {
    if !have_artifacts() {
        return;
    }
    let run = |opt: &str| {
        let mut cfg = TrainConfig::lm("gpt_nano", opt, 1e-3, 25);
        cfg.eval_batches = 4;
        run_config(&cfg).unwrap()
    };
    let adam = run("adam");
    let slim = run("slimadam");
    assert!(!adam.result.diverged && !slim.result.diverged);
    // both learn
    assert!(adam.result.final_train_loss < adam.result.losses[0].1 as f64);
    assert!(slim.result.final_train_loss < slim.result.losses[0].1 as f64);
    // slimadam within a loose band of adam at smoke scale
    let gap = (slim.result.eval_loss - adam.result.eval_loss).abs();
    assert!(gap < 0.5, "eval gap {gap}");
    // and saves the memory the paper claims (>90% on GPT)
    let saving = slim.memory.unwrap().v_saving;
    assert!(saving > 0.9, "saving {saving}");
}

#[test]
fn corpus_data_path_trains() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = TrainConfig::lm("linear2_v256", "adam", 1e-3, 8);
    cfg.data = DataSpec::Corpus;
    cfg.eval_batches = 2;
    let s = run_config(&cfg).unwrap();
    assert!(!s.result.diverged);
    assert!(s.result.eval_loss.is_finite());
}

#[test]
fn fused_engine_smoke() {
    if !std::path::Path::new("artifacts/gpt_nano.train.slimadam.hlo.txt").exists() {
        return;
    }
    let mut cfg = TrainConfig::lm("gpt_nano", "slimadam", 1e-3, 10);
    cfg.engine = EngineKind::Fused("slimadam".into());
    let s = run_config(&cfg).unwrap();
    assert!(!s.result.diverged);
    assert!(s.result.final_train_loss < s.result.losses[0].1 as f64);
}

/// The native-backend end-to-end smoke (CI `native-smoke` job runs the
/// binary equivalent): a tiny MLP trained 50 steps offline with no
/// artifacts must actually learn.
#[test]
fn native_mlp_trains_50_steps_loss_decreases() {
    let mut cfg = TrainConfig::lm("mlp_tiny", "adam", 3e-3, 50);
    cfg.backend = BackendSpec::native();
    cfg.eval_batches = 2;
    let s = run_config(&cfg).unwrap();
    assert!(!s.result.diverged, "native mlp diverged");
    let first = s.result.losses[0].1 as f64;
    assert!(
        s.result.final_train_loss < first - 0.1,
        "native mlp did not learn: {first} -> {}",
        s.result.final_train_loss
    );
    assert!(s.result.eval_loss.is_finite());
}

/// Every optimizer preset trains on the native transformer — the offline
/// analogue of `every_optimizer_trains_gpt_nano`.
#[test]
fn every_optimizer_trains_native_gpt_micro() {
    for opt in slimadam::optim::presets::ALL {
        let mut cfg = TrainConfig::lm("gpt_micro", opt, 3e-4, 5);
        cfg.backend = BackendSpec::native();
        cfg.eval_batches = 0;
        let s = run_config(&cfg).unwrap_or_else(|e| panic!("{opt}: {e:#}"));
        assert!(
            s.result.losses.iter().all(|(_, l)| l.is_finite()),
            "{opt} produced non-finite loss on the native backend"
        );
    }
}

/// Every native zoo family trains end to end through run_config — the
/// offline analogue of `every_model_family_trains` (vision included:
/// `conv_mini` runs on the synthetic image stream).
#[test]
fn every_native_model_family_trains() {
    for model in slimadam::runtime::backend::native::MODELS {
        let mut cfg = TrainConfig::auto(model, "adam", 1e-3, 6);
        cfg.backend = BackendSpec::native();
        cfg.eval_batches = 1;
        let s = run_config(&cfg).unwrap_or_else(|e| panic!("{model}: {e:#}"));
        assert!(!s.result.diverged, "{model} diverged");
        assert!(s.result.final_train_loss.is_finite(), "{model}");
        assert!(s.result.eval_loss.is_finite(), "{model}");
    }
}

/// The conv family learns offline: 60 real steps on the synthetic
/// class-conditional image stream must cut the loss well below the
/// ln(classes) random floor trajectory start.
#[test]
fn native_conv_mini_learns_images() {
    let mut cfg = TrainConfig::auto("conv_mini", "adam", 3e-3, 60);
    cfg.backend = BackendSpec::native();
    cfg.eval_batches = 2;
    let s = run_config(&cfg).unwrap();
    assert!(!s.result.diverged, "conv_mini diverged");
    let first = s.result.losses[0].1 as f64;
    assert!(
        s.result.final_train_loss < first - 0.1,
        "conv_mini did not learn: {first} -> {}",
        s.result.final_train_loss
    );
    assert!(s.result.eval_loss.is_finite());
}

/// Native fused engine end to end through run_config.
#[test]
fn native_fused_engine_smoke() {
    let mut cfg = TrainConfig::lm("gpt_micro", "slimadam", 1e-3, 12);
    cfg.backend = BackendSpec::native();
    cfg.engine = EngineKind::Fused("slimadam".into());
    let s = run_config(&cfg).unwrap();
    assert!(!s.result.diverged);
    assert!(s.result.final_train_loss < s.result.losses[0].1 as f64);
}

#[test]
fn finetune_warm_start_restores_low_loss() {
    if !have_artifacts() {
        return;
    }
    // pre-train briefly, then warm-start on the SAME distribution: the
    // first fine-tune loss must be near the pre-train final loss, far
    // below a fresh init's loss.
    let model = "linear2_v256";
    let Ok(backend) =
        slimadam::runtime::backend::backend_for(&slimadam::runtime::backend::BackendSpec::pjrt())
    else {
        return;
    };
    let engine =
        slimadam::runtime::engine::GradEngine::new("artifacts", model, backend.as_ref()).unwrap();
    let man = engine.manifest().clone();
    let base = TrainConfig::lm(model, "adam", 3e-3, 40);
    let mut rng = slimadam::rng::Rng::new(1);
    let mut params: Vec<slimadam::tensor::Tensor> = man
        .params
        .iter()
        .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
        .collect();
    let mut opt = slimadam::optim::presets::build("adam", &man, base.hypers).unwrap();
    let mut data = slimadam::coordinator::make_data(&man, &base.data, base.seed).unwrap();
    let sched = slimadam::train::Schedule::new(base.lr, base.warmup, base.steps);
    let res = slimadam::train::train_split(
        &engine, opt.as_mut(), &mut params, data.as_mut(), &sched, 40, None, 1, 0,
    )
    .unwrap();

    let mut ft = TrainConfig::lm(model, "adam", 1e-4, 3);
    ft.warm_start = Some(std::sync::Arc::new(params));
    ft.eval_batches = 0;
    let s = run_config(&ft).unwrap();
    let first_ft_loss = s.result.losses[0].1 as f64;
    assert!(
        first_ft_loss < res.losses[0].1 as f64 - 0.2,
        "warm start ineffective: ft starts at {first_ft_loss}, fresh at {}",
        res.losses[0].1
    );
}
