//! Differential test layer for batched dispatch (ISSUE 4, DESIGN.md §12):
//! `slimadam sweep --batch N` must be **bit-for-bit equivalent** to
//! sequential execution. For every builtin native model (split engine)
//! and every builtin ruleset (fused engine), an 8-job sweep is run with
//! batch sizes 1/2/4/8 and the per-job `RunResult::fingerprint`s —
//! which digest every `(step, loss)` pair bit-exactly — must match the
//! sequential run job for job. The grids include diverging LR points so
//! lockstep early-exit is exercised, and a resume-after-kill cycle
//! proves batched stores restore with zero re-execution and no
//! cross-batch bleed.
//!
//! Everything here is real native training (no artifacts, no PJRT, no
//! synthetic mode), so CI always exercises the full contract.

use slimadam::coordinator::{EngineKind, RunSummary, SweepScheduler, TrainConfig};
use slimadam::runstore::{config_key, RunStore, StoreMeta, SCHEMA_VERSION};
use slimadam::runtime::backend::{native, BackendSpec};

fn fingerprints(summaries: &[RunSummary]) -> Vec<u64> {
    summaries.iter().map(|s| s.fingerprint()).collect()
}

/// 8-job split-engine grid on one native model; the top LR diverges.
/// `TrainConfig::auto` picks the family-appropriate workload (tokens for
/// the LM families, synthetic images for `conv_mini`).
fn split_grid(model: &str, steps: usize) -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [5e-4, 1e-3, 2e-3, 10.0] {
            let mut cfg = TrainConfig::auto(model, opt, lr, steps);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    configs
}

/// 8-job fused-engine grid on one native (model, ruleset).
fn fused_grid(model: &str, ruleset: &str, steps: usize) -> Vec<TrainConfig> {
    (0..8)
        .map(|i| {
            let mut cfg = TrainConfig::auto(model, "adam", 4e-4 * (i + 1) as f64, steps);
            cfg.backend = BackendSpec::native();
            cfg.engine = EngineKind::Fused(ruleset.to_string());
            cfg.seed = i as u64;
            cfg
        })
        .collect()
}

fn assert_batched_matches_sequential(configs: &[TrainConfig], what: &str) {
    let sequential = SweepScheduler::new(1).quiet().run(configs).unwrap();
    let seq_fps = fingerprints(&sequential);
    // batch sizes 1/2/4/8, alternating worker counts so whole-group work
    // stealing is exercised alongside single-worker lockstep
    for (batch, workers) in [(1usize, 2usize), (2, 1), (4, 2), (8, 1)] {
        let batched = SweepScheduler::new(workers)
            .quiet()
            .batch(batch)
            .run(configs)
            .unwrap();
        assert_eq!(
            fingerprints(&batched),
            seq_fps,
            "{what}: batch {batch} workers {workers} diverged from sequential"
        );
        // scalar metrics, not just digests
        for (a, b) in sequential.iter().zip(&batched) {
            assert_eq!(a.label, b.label, "{what}");
            assert_eq!(a.result.losses, b.result.losses, "{what}: {}", a.label);
            assert_eq!(a.result.diverged, b.result.diverged, "{what}: {}", a.label);
            assert_eq!(
                a.result.final_train_loss.to_bits(),
                b.result.final_train_loss.to_bits(),
                "{what}: {}",
                a.label
            );
            assert_eq!(
                a.result.eval_loss.to_bits(),
                b.result.eval_loss.to_bits(),
                "{what}: {}",
                a.label
            );
        }
    }
}

/// Split engine (grad_step + Rust optimizer), every builtin model. The
/// lr=10 points diverge mid-run, so jobs leave the lockstep set early.
#[test]
fn batched_split_sweep_matches_sequential_every_model() {
    assert!(!slimadam::coordinator::synthetic_runs_enabled());
    for model in native::MODELS {
        let steps = if *model == "mlp_tiny" { 12 } else { 6 };
        let configs = split_grid(model, steps);
        let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();
        assert!(
            sequential.iter().any(|s| s.result.diverged),
            "{model}: grid must include a diverging point to exercise \
             lockstep early-exit"
        );
        assert!(sequential.iter().any(|s| !s.result.diverged));
        assert_batched_matches_sequential(&configs, &format!("{model} split"));
    }
}

/// Fused engine (single-dispatch train_step), every builtin model ×
/// ruleset — and every bake-off optimizer token (Lion, SGDM, SM3,
/// Adafactor, rank-4 factored V), whose lane kernels ride the same
/// `run_batch ≡ run` contract.
#[test]
fn batched_fused_sweep_matches_sequential_every_ruleset() {
    for model in native::MODELS {
        let steps = if *model == "mlp_tiny" { 10 } else { 5 };
        for ruleset in native::RULESETS.iter().chain(native::OPTIMIZERS) {
            let configs = fused_grid(model, ruleset, steps);
            assert_batched_matches_sequential(
                &configs,
                &format!("{model} fused:{ruleset}"),
            );
        }
    }
}

/// Split engine over the bake-off presets: the Rust optimizers (Lion,
/// SGDM, SM3, Adafactor, rank-4 factored V) stepped by batched
/// grad-dispatch must match sequential bit for bit, same as adam /
/// slimadam above.
#[test]
fn batched_split_bakeoff_matches_sequential() {
    let mut configs = Vec::new();
    for opt in ["lion", "sgdm", "sm3", "adafactor", "lowrank_v"] {
        for lr in [1e-3, 3e-3] {
            let mut cfg = TrainConfig::auto("mlp_tiny", opt, lr, 10);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    assert_batched_matches_sequential(&configs, "mlp_tiny split bake-off");
}

/// Resume-after-kill with batched dispatch: a partial batched sweep
/// (first group only, plus a torn tail from the "kill") resumes under
/// `--batch 4` with zero re-execution, and the final fingerprint set is
/// byte-identical to an uninterrupted sequential run — no cross-batch
/// bleed between restored and freshly batched jobs.
#[test]
fn batched_sweep_resumes_after_kill_byte_identical() {
    let configs = split_grid("mlp_tiny", 10);
    let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();

    let dir = std::env::temp_dir().join("slimadam_batched_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let store = RunStore::open_with(
        &dir,
        &StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 0,
            backend: BackendSpec::native().key(),
        },
    )
    .unwrap();

    // "killed mid-sweep": only the first 4-job group completed...
    let partial = SweepScheduler::new(1)
        .quiet()
        .batch(4)
        .stream_to(store.primary())
        .run(&configs[..4])
        .unwrap();
    assert_eq!(partial.len(), 4);
    {
        // ...and the kill tore the tail of the stream mid-row
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(store.primary())
            .unwrap();
        f.write_all(b"{\"label\":\"mlp_tiny/adam@lr1e-3\",\"final_tr").unwrap();
    }

    let resumed = SweepScheduler::new(2)
        .quiet()
        .batch(4)
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    assert_eq!(
        resumed.iter().filter(|s| s.restored()).count(),
        4,
        "exactly the 4 stored jobs restore; none re-execute"
    );
    assert_eq!(fingerprints(&resumed), fingerprints(&sequential));

    // the merged store holds one clean row per grid point
    let idx = store.index().unwrap();
    assert_eq!(idx.len(), configs.len());
    assert_eq!(idx.stats.torn + idx.stats.skipped, 0, "tail repaired");
    assert_eq!(idx.stats.duplicates + idx.stats.conflicts, 0);
    for cfg in &configs {
        assert!(idx.contains(config_key(cfg)));
    }

    // a second batched resume re-executes nothing at all
    let store2 = RunStore::open(&dir).unwrap();
    let again = SweepScheduler::new(2)
        .quiet()
        .batch(4)
        .resume_from(&store2)
        .unwrap()
        .run(&configs)
        .unwrap();
    assert!(again.iter().all(|s| s.restored()));
    assert_eq!(fingerprints(&again), fingerprints(&sequential));

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Adaptive rule-switching differentials (DESIGN.md §18): the controller
// must be free when it does nothing, and forced V migrations must be
// exact state transformations, not approximations of training.
// ---------------------------------------------------------------------------

use slimadam::coordinator::{make_data, DataSpec};
use slimadam::optim::KMode;
use slimadam::rules::adaptive::AdaptivePolicy;
use slimadam::runtime::backend::backend_for;
use slimadam::runtime::engine::TrainEngine;

/// `--adaptive` with the never-fire policy is bit-identical to the
/// static SlimAdam run it boots from, on every native model: the
/// controller evaluates (cadence 2) but can never cross an infinite
/// threshold, and evaluation itself is read-only. Checked through the
/// scheduler both unbatched and with `--batch 4` (the planner forces
/// adaptive configs into singleton groups, so batching must not change
/// anything either).
#[test]
fn adaptive_never_fire_matches_static_slimadam_bit_exact() {
    let mut never = AdaptivePolicy::never_fire();
    never.every = 2;
    for model in native::MODELS {
        let steps = if *model == "mlp_tiny" { 10 } else { 5 };
        let static_cfgs: Vec<TrainConfig> = fused_grid(model, "slimadam", steps)
            .into_iter()
            .take(4)
            .collect();
        let baseline = SweepScheduler::new(1).quiet().run(&static_cfgs).unwrap();
        let mut adaptive_cfgs = static_cfgs;
        for cfg in &mut adaptive_cfgs {
            cfg.adaptive = Some(never);
        }
        for (batch, workers) in [(1usize, 2usize), (4, 1)] {
            let got = SweepScheduler::new(workers)
                .quiet()
                .batch(batch)
                .run(&adaptive_cfgs)
                .unwrap();
            assert_eq!(
                fingerprints(&got),
                fingerprints(&baseline),
                "{model}: never-fire adaptive diverged from static slimadam \
                 (batch {batch})"
            );
            for (s, b) in got.iter().zip(&baseline) {
                assert_eq!(s.result.losses, b.result.losses, "{model}: {}", s.label);
                let rep = s
                    .adaptive
                    .as_ref()
                    .expect("adaptive summary must carry a report");
                assert!(rep.decisions.is_empty(), "{model}: {:?}", rep.decisions);
                assert!(rep.evals > 0, "{model}: controller never evaluated");
                assert_eq!(
                    rep.timeline.len(),
                    1,
                    "{model}: no switches means the start point only"
                );
            }
        }
    }
}

/// Decompressing every ruled tensor at init turns the fused SlimAdam
/// engine into exact full-V AdamW: expanding all-zero reduced state is
/// exact, and the kernels infer per-tensor mode from the stored V
/// length, so the loss stream is bit-identical to a from-scratch fused
/// Adam engine fed the same batches.
#[test]
fn decompress_at_init_matches_full_v_adam_bit_exact() {
    let backend = backend_for(&BackendSpec::native()).unwrap();
    for model in ["mlp_tiny", "gpt_micro"] {
        let mut slim =
            TrainEngine::new("artifacts", model, "slimadam", backend.as_ref(), "mitchell", 5)
                .unwrap();
        let mut adam =
            TrainEngine::new("artifacts", model, "adam", backend.as_ref(), "mitchell", 5)
                .unwrap();
        let man = slim.manifest().clone();
        let k_modes = man.k_modes.clone().expect("slimadam bakes k_modes");
        for (i, &k) in k_modes.iter().enumerate() {
            if k != KMode::None {
                slim.migrate_v(i, k, KMode::None).unwrap();
            }
        }
        assert_eq!(
            slim.v_elem_counts().unwrap().iter().sum::<usize>(),
            man.total_param_elems(),
            "{model}: decompressed engine must store full V"
        );
        let mut data = make_data(&man, &DataSpec::default_for(&man), 11).unwrap();
        for t in 0..8 {
            let batch = data.next_batch();
            let a = slim.step(&batch, 1e-3).unwrap();
            let b = adam.step(&batch, 1e-3).unwrap();
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{model} step {t}: decompressed slimadam != full-V adam"
            );
        }
    }
}

/// The forced round trip on live state: train reduced, expand every
/// ruled tensor, train full, collapse back, train reduced again. A
/// collapse immediately after an expand must give back each reduced
/// entry to f32 summation tolerance (the broadcast made every group
/// constant — DESIGN.md §18's documented tolerance), the engine keeps
/// stepping through both migrations, and the whole forced schedule is
/// deterministic: a twin engine driven identically reproduces losses
/// and final V state bit for bit.
#[test]
fn forced_compress_decompress_round_trip() {
    let backend = backend_for(&BackendSpec::native()).unwrap();
    let model = "gpt_micro";
    let mk = || {
        TrainEngine::new("artifacts", model, "slimadam", backend.as_ref(), "mitchell", 9)
            .unwrap()
    };
    let mut a = mk();
    let mut b = mk();
    let man = a.manifest().clone();
    let k_modes = man.k_modes.clone().unwrap();
    let ruled: Vec<usize> = k_modes
        .iter()
        .enumerate()
        .filter(|&(_, &k)| k != KMode::None)
        .map(|(i, _)| i)
        .collect();
    assert!(!ruled.is_empty(), "{model} slimadam rules no tensor?");

    // tolerance leg: a third engine expands then immediately collapses —
    // the group mean of a broadcast must reproduce the reduced entries
    let mut c = mk();
    let mut data_c = make_data(&man, &DataSpec::default_for(&man), 23).unwrap();
    for _ in 0..4 {
        let batch = data_c.next_batch();
        c.step(&batch, 1e-3).unwrap();
    }
    let v0 = c.second_moments().unwrap();
    for &i in &ruled {
        c.migrate_v(i, k_modes[i], KMode::None).unwrap();
        c.migrate_v(i, KMode::None, k_modes[i]).unwrap();
    }
    let v1 = c.second_moments().unwrap();
    for &i in &ruled {
        for (j, (x, y)) in v0[i].data.iter().zip(&v1[i].data).enumerate() {
            let tol = 1e-6 * x.abs().max(1e-12) + 1e-9;
            assert!(
                (x - y).abs() <= tol,
                "{}[{j}]: {x} -> {y} after expand+collapse",
                man.params[i].name
            );
        }
    }
    let batch = data_c.next_batch();
    assert!(c.step(&batch, 1e-3).unwrap().loss.is_finite());

    // determinism leg: twin engines through the full forced schedule
    let mut data = make_data(&man, &DataSpec::default_for(&man), 23).unwrap();
    let mut losses_a = Vec::new();
    let mut losses_b = Vec::new();
    for phase in 0..3 {
        if phase == 1 {
            for &i in &ruled {
                a.migrate_v(i, k_modes[i], KMode::None).unwrap();
                b.migrate_v(i, k_modes[i], KMode::None).unwrap();
            }
        }
        if phase == 2 {
            for &i in &ruled {
                a.migrate_v(i, KMode::None, k_modes[i]).unwrap();
                b.migrate_v(i, KMode::None, k_modes[i]).unwrap();
            }
        }
        for _ in 0..4 {
            let batch = data.next_batch();
            losses_a.push(a.step(&batch, 1e-3).unwrap().loss);
            losses_b.push(b.step(&batch, 1e-3).unwrap().loss);
        }
    }
    assert!(losses_a.iter().all(|l| l.is_finite()), "{losses_a:?}");
    assert_eq!(
        losses_a.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        losses_b.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "forced migration schedule must be deterministic"
    );
    let va = a.second_moments().unwrap();
    let vb = b.second_moments().unwrap();
    for &i in &ruled {
        assert_eq!(va[i].data, vb[i].data, "{}", man.params[i].name);
    }
    // and storage ended reduced again, at the baked shapes
    let baked: Vec<usize> = man
        .v_shapes
        .as_ref()
        .unwrap()
        .iter()
        .map(|s| s.iter().product())
        .collect();
    assert_eq!(a.v_elem_counts().unwrap(), baked);
}

/// Batched rows must be byte-compatible with unbatched rows: resuming a
/// store written by a batched sweep with an *unbatched* scheduler (and
/// vice versa) restores every job.
#[test]
fn batched_and_unbatched_stores_are_interchangeable() {
    let configs = split_grid("mlp_tiny", 8);

    let dir = std::env::temp_dir().join("slimadam_batched_interop");
    let _ = std::fs::remove_dir_all(&dir);
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .batch(8)
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    // unbatched resume of a batched store: everything restores
    let resumed = SweepScheduler::new(1)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .run(&configs)
        .unwrap();
    assert!(resumed.iter().all(|s| s.restored()));

    // and the stored fingerprints equal a live sequential run's
    let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();
    assert_eq!(fingerprints(&resumed), fingerprints(&sequential));

    let _ = std::fs::remove_dir_all(&dir);
}
