//! Differential test layer for batched dispatch (ISSUE 4, DESIGN.md §12):
//! `slimadam sweep --batch N` must be **bit-for-bit equivalent** to
//! sequential execution. For every builtin native model (split engine)
//! and every builtin ruleset (fused engine), an 8-job sweep is run with
//! batch sizes 1/2/4/8 and the per-job `RunResult::fingerprint`s —
//! which digest every `(step, loss)` pair bit-exactly — must match the
//! sequential run job for job. The grids include diverging LR points so
//! lockstep early-exit is exercised, and a resume-after-kill cycle
//! proves batched stores restore with zero re-execution and no
//! cross-batch bleed.
//!
//! Everything here is real native training (no artifacts, no PJRT, no
//! synthetic mode), so CI always exercises the full contract.

use slimadam::coordinator::{EngineKind, RunSummary, SweepScheduler, TrainConfig};
use slimadam::runstore::{config_key, RunStore, StoreMeta, SCHEMA_VERSION};
use slimadam::runtime::backend::{native, BackendSpec};

fn fingerprints(summaries: &[RunSummary]) -> Vec<u64> {
    summaries.iter().map(|s| s.fingerprint()).collect()
}

/// 8-job split-engine grid on one native model; the top LR diverges.
/// `TrainConfig::auto` picks the family-appropriate workload (tokens for
/// the LM families, synthetic images for `conv_mini`).
fn split_grid(model: &str, steps: usize) -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [5e-4, 1e-3, 2e-3, 10.0] {
            let mut cfg = TrainConfig::auto(model, opt, lr, steps);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    configs
}

/// 8-job fused-engine grid on one native (model, ruleset).
fn fused_grid(model: &str, ruleset: &str, steps: usize) -> Vec<TrainConfig> {
    (0..8)
        .map(|i| {
            let mut cfg = TrainConfig::auto(model, "adam", 4e-4 * (i + 1) as f64, steps);
            cfg.backend = BackendSpec::native();
            cfg.engine = EngineKind::Fused(ruleset.to_string());
            cfg.seed = i as u64;
            cfg
        })
        .collect()
}

fn assert_batched_matches_sequential(configs: &[TrainConfig], what: &str) {
    let sequential = SweepScheduler::new(1).quiet().run(configs).unwrap();
    let seq_fps = fingerprints(&sequential);
    // batch sizes 1/2/4/8, alternating worker counts so whole-group work
    // stealing is exercised alongside single-worker lockstep
    for (batch, workers) in [(1usize, 2usize), (2, 1), (4, 2), (8, 1)] {
        let batched = SweepScheduler::new(workers)
            .quiet()
            .batch(batch)
            .run(configs)
            .unwrap();
        assert_eq!(
            fingerprints(&batched),
            seq_fps,
            "{what}: batch {batch} workers {workers} diverged from sequential"
        );
        // scalar metrics, not just digests
        for (a, b) in sequential.iter().zip(&batched) {
            assert_eq!(a.label, b.label, "{what}");
            assert_eq!(a.result.losses, b.result.losses, "{what}: {}", a.label);
            assert_eq!(a.result.diverged, b.result.diverged, "{what}: {}", a.label);
            assert_eq!(
                a.result.final_train_loss.to_bits(),
                b.result.final_train_loss.to_bits(),
                "{what}: {}",
                a.label
            );
            assert_eq!(
                a.result.eval_loss.to_bits(),
                b.result.eval_loss.to_bits(),
                "{what}: {}",
                a.label
            );
        }
    }
}

/// Split engine (grad_step + Rust optimizer), every builtin model. The
/// lr=10 points diverge mid-run, so jobs leave the lockstep set early.
#[test]
fn batched_split_sweep_matches_sequential_every_model() {
    assert!(!slimadam::coordinator::synthetic_runs_enabled());
    for model in native::MODELS {
        let steps = if *model == "mlp_tiny" { 12 } else { 6 };
        let configs = split_grid(model, steps);
        let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();
        assert!(
            sequential.iter().any(|s| s.result.diverged),
            "{model}: grid must include a diverging point to exercise \
             lockstep early-exit"
        );
        assert!(sequential.iter().any(|s| !s.result.diverged));
        assert_batched_matches_sequential(&configs, &format!("{model} split"));
    }
}

/// Fused engine (single-dispatch train_step), every builtin model ×
/// ruleset — and every bake-off optimizer token (Lion, SGDM, SM3,
/// Adafactor, rank-4 factored V), whose lane kernels ride the same
/// `run_batch ≡ run` contract.
#[test]
fn batched_fused_sweep_matches_sequential_every_ruleset() {
    for model in native::MODELS {
        let steps = if *model == "mlp_tiny" { 10 } else { 5 };
        for ruleset in native::RULESETS.iter().chain(native::OPTIMIZERS) {
            let configs = fused_grid(model, ruleset, steps);
            assert_batched_matches_sequential(
                &configs,
                &format!("{model} fused:{ruleset}"),
            );
        }
    }
}

/// Split engine over the bake-off presets: the Rust optimizers (Lion,
/// SGDM, SM3, Adafactor, rank-4 factored V) stepped by batched
/// grad-dispatch must match sequential bit for bit, same as adam /
/// slimadam above.
#[test]
fn batched_split_bakeoff_matches_sequential() {
    let mut configs = Vec::new();
    for opt in ["lion", "sgdm", "sm3", "adafactor", "lowrank_v"] {
        for lr in [1e-3, 3e-3] {
            let mut cfg = TrainConfig::auto("mlp_tiny", opt, lr, 10);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    assert_batched_matches_sequential(&configs, "mlp_tiny split bake-off");
}

/// Resume-after-kill with batched dispatch: a partial batched sweep
/// (first group only, plus a torn tail from the "kill") resumes under
/// `--batch 4` with zero re-execution, and the final fingerprint set is
/// byte-identical to an uninterrupted sequential run — no cross-batch
/// bleed between restored and freshly batched jobs.
#[test]
fn batched_sweep_resumes_after_kill_byte_identical() {
    let configs = split_grid("mlp_tiny", 10);
    let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();

    let dir = std::env::temp_dir().join("slimadam_batched_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let store = RunStore::open_with(
        &dir,
        &StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 0,
            backend: BackendSpec::native().key(),
        },
    )
    .unwrap();

    // "killed mid-sweep": only the first 4-job group completed...
    let partial = SweepScheduler::new(1)
        .quiet()
        .batch(4)
        .stream_to(store.primary())
        .run(&configs[..4])
        .unwrap();
    assert_eq!(partial.len(), 4);
    {
        // ...and the kill tore the tail of the stream mid-row
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(store.primary())
            .unwrap();
        f.write_all(b"{\"label\":\"mlp_tiny/adam@lr1e-3\",\"final_tr").unwrap();
    }

    let resumed = SweepScheduler::new(2)
        .quiet()
        .batch(4)
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    assert_eq!(
        resumed.iter().filter(|s| s.restored()).count(),
        4,
        "exactly the 4 stored jobs restore; none re-execute"
    );
    assert_eq!(fingerprints(&resumed), fingerprints(&sequential));

    // the merged store holds one clean row per grid point
    let idx = store.index().unwrap();
    assert_eq!(idx.len(), configs.len());
    assert_eq!(idx.stats.torn + idx.stats.skipped, 0, "tail repaired");
    assert_eq!(idx.stats.duplicates + idx.stats.conflicts, 0);
    for cfg in &configs {
        assert!(idx.contains(config_key(cfg)));
    }

    // a second batched resume re-executes nothing at all
    let store2 = RunStore::open(&dir).unwrap();
    let again = SweepScheduler::new(2)
        .quiet()
        .batch(4)
        .resume_from(&store2)
        .unwrap()
        .run(&configs)
        .unwrap();
    assert!(again.iter().all(|s| s.restored()));
    assert_eq!(fingerprints(&again), fingerprints(&sequential));

    let _ = std::fs::remove_dir_all(&dir);
}

/// Batched rows must be byte-compatible with unbatched rows: resuming a
/// store written by a batched sweep with an *unbatched* scheduler (and
/// vice versa) restores every job.
#[test]
fn batched_and_unbatched_stores_are_interchangeable() {
    let configs = split_grid("mlp_tiny", 8);

    let dir = std::env::temp_dir().join("slimadam_batched_interop");
    let _ = std::fs::remove_dir_all(&dir);
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .batch(8)
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();

    // unbatched resume of a batched store: everything restores
    let resumed = SweepScheduler::new(1)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .run(&configs)
        .unwrap();
    assert!(resumed.iter().all(|s| s.restored()));

    // and the stored fingerprints equal a live sequential run's
    let sequential = SweepScheduler::new(1).quiet().run(&configs).unwrap();
    assert_eq!(fingerprints(&resumed), fingerprints(&sequential));

    let _ = std::fs::remove_dir_all(&dir);
}
