//! Acceptance test for the native backend (ISSUE 3): a *real* —
//! non-synthetic — sweep of 8 grid points over adam/slimadam completes
//! offline (no artifacts, no PJRT, no `SLIMADAM_SYNTH_RUNS`), resumes
//! from a partial run store with zero re-execution, and the adam vs
//! slimadam runs reproduce the reduced-V memory accounting in
//! `optim::memory::report`.

use slimadam::coordinator::{SweepScheduler, TrainConfig};
use slimadam::runstore::{config_key, RunStore, StoreMeta, SCHEMA_VERSION};
use slimadam::runtime::backend::BackendSpec;

fn grid() -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [5e-4, 1e-3, 2e-3, 4e-3] {
            let mut cfg = TrainConfig::lm("mlp_tiny", opt, lr, 20);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    configs
}

fn tmp_store(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("slimadam_native_sweep_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn native_sweep_resumes_with_zero_reexecution() {
    assert!(!slimadam::coordinator::synthetic_runs_enabled());
    let configs = grid();
    assert_eq!(configs.len(), 8);

    // Baseline: the full grid, fresh. Real training: losses recorded,
    // memory reports attached, nothing restored.
    let baseline = SweepScheduler::new(2).quiet().run(&configs).unwrap();
    assert!(baseline.iter().all(|s| !s.restored()));
    assert!(baseline
        .iter()
        .all(|s| !s.result.losses.is_empty() && s.result.final_train_loss.is_finite()));

    // --- reduced-V memory accounting (optim::memory::report) ---
    let adam_mem = baseline[0].memory.as_ref().unwrap();
    let slim_mem = baseline[4].memory.as_ref().unwrap();
    assert_eq!(
        adam_mem.v_elems, adam_mem.param_elems,
        "adam stores one second moment per parameter"
    );
    assert!(adam_mem.v_saving.abs() < 1e-12);
    assert!(
        slim_mem.v_elems < adam_mem.v_elems / 5,
        "slimadam must store far fewer second moments: {} vs {}",
        slim_mem.v_elems,
        adam_mem.v_elems
    );
    assert!(slim_mem.v_saving > 0.9, "saving {}", slim_mem.v_saving);

    // --- partial run, then resume: zero re-execution ---
    let dir = tmp_store("resume");
    let store = RunStore::open_with(
        &dir,
        &StoreMeta {
            schema_version: SCHEMA_VERSION,
            base_seed: 0,
            backend: BackendSpec::native().key(),
        },
    )
    .unwrap();
    let partial = SweepScheduler::new(2)
        .quiet()
        .stream_to(store.primary())
        .run(&configs[..5])
        .unwrap();
    assert_eq!(partial.len(), 5);

    let resumed = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store)
        .unwrap()
        .stream_to(store.primary())
        .run(&configs)
        .unwrap();
    let restored = resumed.iter().filter(|s| s.restored()).count();
    assert_eq!(restored, 5, "first resume must skip exactly the 5 stored jobs");

    // every fingerprint — restored or freshly run — matches the fresh
    // baseline: resume changed nothing about the metrics
    for (a, b) in baseline.iter().zip(&resumed) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.fingerprint(), b.fingerprint(), "{}", a.label);
    }

    // a second resume re-executes nothing at all
    let store2 = RunStore::open(&dir).unwrap();
    let again = SweepScheduler::new(2)
        .quiet()
        .resume_from(&store2)
        .unwrap()
        .run(&configs)
        .unwrap();
    assert_eq!(again.iter().filter(|s| s.restored()).count(), 8);

    // the store indexed one row per distinct config key
    let idx = store2.index().unwrap();
    assert_eq!(idx.len(), 8);
    for cfg in &configs {
        assert!(idx.contains(config_key(cfg)));
    }

    // store manifest records backend + schema
    let meta = store2.meta().unwrap();
    assert_eq!(meta.schema_version, SCHEMA_VERSION);
    assert_eq!(meta.backend, "native@cpu:0");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Mixed-backend stores stay coherent: a native row can never be served
/// for a pjrt config of otherwise-identical shape (config keys differ).
#[test]
fn resume_never_crosses_backends() {
    let mut native = TrainConfig::lm("mlp_tiny", "adam", 1e-3, 10);
    native.backend = BackendSpec::native();
    let mut pjrt = native.clone();
    pjrt.backend = BackendSpec::pjrt();
    assert_ne!(config_key(&native), config_key(&pjrt));

    let dir = tmp_store("crossback");
    let store = RunStore::open(&dir).unwrap();
    SweepScheduler::new(1)
        .quiet()
        .stream_to(store.primary())
        .run(std::slice::from_ref(&native))
        .unwrap();
    let idx = store.index().unwrap();
    assert!(idx.contains(config_key(&native)));
    assert!(!idx.contains(config_key(&pjrt)));
    let _ = std::fs::remove_dir_all(&dir);
}
