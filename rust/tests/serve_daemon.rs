//! End-to-end daemon tests (ISSUE 8 tentpole): multi-tenant submits are
//! deterministic regardless of arrival order and byte-identical to a
//! direct `slimadam sweep`; the bounded queue, cancel, and drain state
//! machine behave as specified; and a SIGKILLed daemon replays its
//! durable queue on restart and resumes mid-batch with zero
//! re-execution.
//!
//! All sweeps run synthetically (`SLIMADAM_SYNTH_RUNS=1`) so rows carry
//! no timing fields and fingerprints are exact. Env mutations are
//! process-global, so every test serializes on `ENV_LOCK`.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use slimadam::coordinator::SweepScheduler;
use slimadam::json::Value;
use slimadam::runstore::{config_key, RunStore};
use slimadam::serve::queue::DurableQueue;
use slimadam::serve::{spawn, Client, JobSpec, ServeOpts};

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn lock_env() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("slimadam_serve_{}_{}", name, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sock(dir: &Path) -> String {
    dir.join("serve.sock").to_str().unwrap().to_string()
}

/// Run the spec directly through the scheduler (the one-shot `sweep`
/// path) and collect sorted `(config_key, fingerprint)` pairs.
fn direct_pairs(spec: &JobSpec) -> Vec<(u64, u64)> {
    let configs = spec.expand().unwrap();
    let summaries = SweepScheduler::new(2).quiet().run(&configs).unwrap();
    let mut pairs: Vec<(u64, u64)> = configs
        .iter()
        .zip(&summaries)
        .map(|(c, s)| (config_key(c), s.fingerprint()))
        .collect();
    pairs.sort_unstable();
    pairs
}

/// Sorted `(config_key, fingerprint)` pairs from a tenant run store.
fn store_pairs(dir: &Path) -> Vec<(u64, u64)> {
    RunStore::open(dir).unwrap().index().unwrap().fingerprints()
}

fn sorted_lines(path: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).unwrap();
    let mut v: Vec<String> = text.lines().map(String::from).collect();
    v.sort();
    v
}

/// Find `job` in a status reply's `jobs` array.
fn job_entry<'a>(status: &'a Value, job: &str) -> Option<&'a Value> {
    status
        .get("jobs")
        .ok()?
        .as_arr()
        .ok()?
        .iter()
        .find(|e| e.get("job").and_then(|j| Ok(j.as_str()? == job)).unwrap_or(false))
}

/// Poll `status` until `job` reaches `want` state.
fn wait_state(client: &mut Client, job: &str, want: &str, timeout: Duration) -> Value {
    let deadline = Instant::now() + timeout;
    loop {
        let st = client.status().unwrap();
        if let Some(entry) = job_entry(&st, job) {
            if entry.get("state").unwrap().as_str().unwrap() == want {
                return entry.clone();
            }
        }
        assert!(
            Instant::now() < deadline,
            "job {job} never reached state {want}; last status: {}",
            st.dump()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Submit `spec` on its own connection with `watch`, assert it queues,
/// and return `(client, job_id)`.
fn submit_watch(addr: &str, tenant: &str, spec: &JobSpec) -> (Client, String) {
    let mut client = Client::connect_retry(addr, Duration::from_secs(10)).unwrap();
    let reply = client.submit(tenant, spec, true).unwrap();
    assert_eq!(
        reply.get("reply").unwrap().as_str().unwrap(),
        "queued",
        "{}",
        reply.dump()
    );
    let job = reply.get("job").unwrap().as_str().unwrap().to_string();
    (client, job)
}

/// Wait for `job` on its watching connection; returns rows seen.
fn finish(client: &mut Client, job: &str) -> usize {
    let mut rows = 0usize;
    let done = client.wait_job(job, |_| rows += 1).unwrap();
    assert!(
        !done.opt("failed").and_then(|b| b.as_bool().ok()).unwrap_or(false),
        "job {job} failed: {}",
        done.dump()
    );
    rows
}

/// Tentpole determinism invariant: two tenants submitting interleaved
/// jobs get stores whose fingerprints match a direct one-shot sweep of
/// the same spec — in either arrival order — and (clean shutdown +
/// synthetic timing) the store bytes match the direct stream exactly.
#[test]
fn two_tenants_interleaved_match_direct_sweeps_in_any_order() {
    let _env = lock_env();
    std::env::remove_var("SLIMADAM_SYNTH_MS");
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");

    let alice = JobSpec::native("mlp_tiny", &["adam", "slimadam"], &[1e-3, 3e-3], 12);
    let mut bob = JobSpec::native("gpt_micro", &["adam"], &[5e-4, 1e-3, 2e-3], 9);
    bob.seed = 7;
    let want_alice = direct_pairs(&alice);
    let want_bob = direct_pairs(&bob);

    for ordering in ["ab", "ba"] {
        let dir = tmp(&format!("order_{ordering}"));
        let state = dir.join("state");
        let addr = sock(&dir);
        let handle = spawn(ServeOpts {
            addr: addr.clone(),
            state_dir: state.clone(),
            workers: 2,
            max_batch: 8,
            queue_cap: 8,
            quiet: true,
        })
        .unwrap();

        let (mut c1, j1, mut c2, j2) = if ordering == "ab" {
            let (ca, ja) = submit_watch(&addr, "alice", &alice);
            let (cb, jb) = submit_watch(&addr, "bob", &bob);
            (ca, ja, cb, jb)
        } else {
            let (cb, jb) = submit_watch(&addr, "bob", &bob);
            let (ca, ja) = submit_watch(&addr, "alice", &alice);
            (cb, jb, ca, ja)
        };
        let rows1 = finish(&mut c1, &j1);
        let rows2 = finish(&mut c2, &j2);
        let (alice_rows, bob_rows) =
            if ordering == "ab" { (rows1, rows2) } else { (rows2, rows1) };
        assert_eq!(alice_rows, alice.n_configs(), "alice row stream");
        assert_eq!(bob_rows, bob.n_configs(), "bob row stream");

        let mut admin = Client::connect(&addr).unwrap();
        let reply = admin.drain().unwrap();
        assert_eq!(reply.get("reply").unwrap().as_str().unwrap(), "draining");
        handle.join().unwrap();

        assert_eq!(
            store_pairs(&state.join("tenants/alice")),
            want_alice,
            "ordering {ordering}: alice fingerprints drift from direct sweep"
        );
        assert_eq!(
            store_pairs(&state.join("tenants/bob")),
            want_bob,
            "ordering {ordering}: bob fingerprints drift from direct sweep"
        );
        assert!(
            !Path::new(&addr).exists(),
            "drain must unlink the unix socket"
        );

        if ordering == "ab" {
            // Byte-level identity: synthetic rows carry zero timing, so
            // the daemon's store stream must equal a direct streaming
            // sweep line for line (order aside).
            let stream = dir.join("direct.jsonl");
            SweepScheduler::new(2)
                .quiet()
                .stream_to(&stream)
                .run(&alice.expand().unwrap())
                .unwrap();
            assert_eq!(
                sorted_lines(&state.join("tenants/alice/stream.jsonl")),
                sorted_lines(&stream),
                "daemon rows must be byte-identical to one-shot sweep rows"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
    std::env::remove_var("SLIMADAM_SYNTH_RUNS");
}

/// Queue-discipline tour: status reporting, bounded-queue `overloaded`,
/// cancel semantics, and drain rejections — all while a slow wave holds
/// the single worker.
#[test]
fn status_overload_cancel_and_draining_rejections() {
    let _env = lock_env();
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
    std::env::set_var("SLIMADAM_SYNTH_MS", "150");

    let dir = tmp("queue");
    let state = dir.join("state");
    let addr = sock(&dir);
    let handle = spawn(ServeOpts {
        addr: addr.clone(),
        state_dir: state.clone(),
        workers: 1,
        max_batch: 1,
        queue_cap: 2,
        quiet: true,
    })
    .unwrap();
    let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    assert!(client.ping().unwrap());

    // 8 configs × 150 ms on one worker ≈ 1.2 s — the wave outlives
    // everything below.
    let slow = JobSpec::native(
        "mlp_tiny",
        &["adam"],
        &[1e-4, 2e-4, 3e-4, 4e-4, 5e-4, 6e-4, 7e-4, 8e-4],
        5,
    );
    let r1 = client.submit("alice", &slow, false).unwrap();
    let job1 = r1.get("job").unwrap().as_str().unwrap().to_string();
    wait_state(&mut client, &job1, "running", Duration::from_secs(10));

    // worker busy → this one queues; live = running + queued = cap
    let quick = JobSpec::native("mlp_tiny", &["adam"], &[9e-4], 5);
    let r2 = client.submit("bob", &quick, false).unwrap();
    assert_eq!(r2.get("reply").unwrap().as_str().unwrap(), "queued");
    let job2 = r2.get("job").unwrap().as_str().unwrap().to_string();

    // at capacity → explicit Overloaded, nothing journaled
    let r3 = client.submit("carol", &quick, false).unwrap();
    assert_eq!(r3.get("reply").unwrap().as_str().unwrap(), "overloaded");
    assert_eq!(r3.get("queue_depth").unwrap().as_usize().unwrap(), 2);
    assert_eq!(r3.get("queue_cap").unwrap().as_usize().unwrap(), 2);

    let st = client.status().unwrap();
    assert_eq!(st.get("live").unwrap().as_usize().unwrap(), 2);
    assert!(st.get("queued").unwrap().as_usize().unwrap() >= 1);
    assert!(!st.get("draining").unwrap().as_bool().unwrap());
    assert!(job_entry(&st, &job1).is_some());
    assert!(job_entry(&st, &job2).is_some());

    // cancel is once-only and queued-only
    assert!(client.cancel(&job2).unwrap(), "queued job must cancel");
    assert!(!client.cancel(&job2).unwrap(), "second cancel is a no-op");
    assert!(!client.cancel(&job1).unwrap(), "running job is not cancellable");

    let reply = client.drain().unwrap();
    assert_eq!(reply.get("reply").unwrap().as_str().unwrap(), "draining");
    // draining daemon stops admitting but finishes job1
    let rejected = client.submit("dave", &quick, false).unwrap();
    assert_eq!(rejected.get("reply").unwrap().as_str().unwrap(), "draining");
    handle.join().unwrap();

    // journal closed the books: job1 done, job2 tombstoned by cancel
    let q = DurableQueue::open(&state, 8).unwrap();
    assert_eq!(q.queued(), 0, "drained daemon must leave an empty queue");

    std::fs::remove_dir_all(&dir).ok();
    std::env::remove_var("SLIMADAM_SYNTH_MS");
    std::env::remove_var("SLIMADAM_SYNTH_RUNS");
}

/// Durability acceptance test: SIGKILL the daemon mid-batch, restart it,
/// and the replayed queue resumes the job — completed rows are skipped
/// (zero re-execution), fingerprints match a direct sweep, and a
/// SIGTERM drain exits 0.
#[test]
fn sigkill_mid_batch_replays_and_resumes_with_zero_reexecution() {
    let _env = lock_env();
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
    std::env::remove_var("SLIMADAM_SYNTH_MS");

    let spec = JobSpec::native(
        "mlp_tiny",
        &["adam", "slimadam"],
        &[1e-4, 3e-4, 1e-3, 3e-3],
        10,
    );
    let want = direct_pairs(&spec);
    assert_eq!(want.len(), 8);

    let dir = tmp("sigkill");
    let state = dir.join("state");
    let addr = sock(&dir);
    let tenant_dir = state.join("tenants/alice");
    let bin = env!("CARGO_BIN_EXE_slimadam");
    let serve_args = |a: &str| {
        vec![
            "serve".to_string(),
            "--addr".to_string(),
            a.to_string(),
            "--state-dir".to_string(),
            state.to_str().unwrap().to_string(),
            "--workers".to_string(),
            "2".to_string(),
            "--quiet".to_string(),
            "--synthetic".to_string(),
        ]
    };

    // first daemon: slow synthetic steps so the kill lands mid-batch
    let mut child1 = Command::new(bin)
        .args(serve_args(&addr))
        .env("SLIMADAM_SYNTH_MS", "150")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut client = Client::connect_retry(&addr, Duration::from_secs(15)).unwrap();
    let reply = client.submit("alice", &spec, false).unwrap();
    assert_eq!(reply.get("reply").unwrap().as_str().unwrap(), "queued");
    let job = reply.get("job").unwrap().as_str().unwrap().to_string();

    // wait until at least one row hit the tenant store, then SIGKILL
    let primary = tenant_dir.join("stream.jsonl");
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let done_rows = std::fs::read(&primary)
            .map(|b| b.iter().filter(|&&c| c == b'\n').count())
            .unwrap_or(0);
        if done_rows >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "no rows before kill");
        std::thread::sleep(Duration::from_millis(5));
    }
    child1.kill().unwrap();
    child1.wait().unwrap();
    drop(client);

    // complete rows on disk at the moment of death = what the restart
    // may skip; anything torn re-runs
    let bytes = std::fs::read(&primary).unwrap();
    let rows_before = bytes.iter().filter(|&&c| c == b'\n').count();
    assert!(rows_before >= 1);

    // second daemon: same state dir, full speed — must replay the
    // journal (the job was never journaled done) and resume
    let mut child2 = Command::new(bin)
        .args(serve_args(&addr))
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut client = Client::connect_retry(&addr, Duration::from_secs(15)).unwrap();
    let entry = wait_state(&mut client, &job, "done", Duration::from_secs(30));
    assert_eq!(
        entry.get("skipped").unwrap().as_usize().unwrap(),
        rows_before,
        "resume must skip exactly the rows that survived the kill"
    );
    assert_eq!(
        entry.get("ran").unwrap().as_usize().unwrap(),
        spec.n_configs() - rows_before,
        "resume must run exactly the remainder"
    );

    // zero re-execution: 8 unique configs, no duplicate rows, and the
    // fingerprints are exactly the direct sweep's
    let store = RunStore::open(&tenant_dir).unwrap();
    let (_, idx) = store.ls().unwrap();
    assert_eq!(idx.stats.duplicates, 0, "replay re-executed a config");
    let pairs = store_pairs(&tenant_dir);
    assert_eq!(pairs.len(), 8);
    assert_eq!(pairs, want, "post-crash fingerprints drift from direct sweep");

    // graceful SIGTERM drain: exit 0 with the socket unlinked
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;
    assert_eq!(unsafe { kill(child2.id() as i32, SIGTERM) }, 0);
    let status = child2.wait().unwrap();
    assert!(status.success(), "SIGTERM drain must exit 0, got {status:?}");
    assert!(!Path::new(&addr).exists(), "drain must unlink the socket");

    std::fs::remove_dir_all(&dir).ok();
    std::env::remove_var("SLIMADAM_SYNTH_RUNS");
}
