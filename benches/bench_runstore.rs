//! Run-store scan throughput: the startup cost of `--resume` is one full
//! index rebuild over every stream file, so the streaming reader must
//! stay I/O-bound. Three measurements over the same synthetic stream:
//!
//! * `runstore_scan_events` — raw event scan, the zero-copy floor;
//! * `runstore_index_build` — full `RunIndex` construction (events +
//!   entry extraction + hash insert), what resume actually pays;
//! * `runstore_dom_baseline` — per-line `Value::parse`, the DOM cost the
//!   streaming reader exists to avoid.

use slimadam::benchkit::Bencher;
use slimadam::json::Value;
use slimadam::runstore::{scan_jsonl, RunIndex, Tolerance};

/// One realistic sweep row (~240 bytes, a couple of escapes, a nested
/// memory object — matches what the scheduler streams).
fn row(i: u64) -> String {
    format!(
        concat!(
            r#"{{"config_key":"{key:016x}","fingerprint":"{fp:016x}","seed":"{seed:016x}","#,
            r#""job":{job},"label":"gpt_nano/adam@lr{lr:.0e}","model":"gpt_nano","optimizer":"adam","#,
            r#""lr":{lr},"final_train_loss":{loss:.6},"eval_loss":{eval:.6},"diverged":false,"#,
            r#""steps":100,"steps_per_s":88.5,"wallclock_s":1.13,"#,
            r#""memory":{{"m_elems":1000,"v_elems":500,"note":"50% \"saved\""}}}}"#
        ),
        key = i.wrapping_mul(0x9E3779B97F4A7C15),
        fp = i.wrapping_mul(0xD1B54A32D192ED03),
        seed = i,
        job = i,
        lr = 1e-3 + i as f64 * 1e-6,
        loss = 2.0 + (i % 97) as f64 * 0.01,
        eval = 2.1 + (i % 89) as f64 * 0.01,
    )
}

fn main() {
    let n_rows: usize = if std::env::var("SLIMADAM_BENCH_FAST").is_ok() {
        2_000
    } else {
        20_000
    };
    let text: String = (0..n_rows as u64).map(|i| row(i) + "\n").collect();
    let bytes = text.len();
    println!(
        "== runstore scan throughput ({n_rows} rows, {:.1} MiB) ==",
        bytes as f64 / (1024.0 * 1024.0)
    );

    let b = Bencher::default();

    b.bench_bytes("runstore_scan_events", bytes, || {
        let mut fields = 0usize;
        let stats = scan_jsonl(&text, Tolerance::TornTail, &mut |_, row| {
            fields += row.fields.len();
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.rows, n_rows);
        std::hint::black_box(fields);
    });

    b.bench_bytes("runstore_index_build", bytes, || {
        let mut idx = RunIndex::new();
        idx.scan_text(&text).unwrap();
        assert_eq!(idx.len(), n_rows);
        std::hint::black_box(idx.len());
    });

    b.bench_bytes("runstore_dom_baseline", bytes, || {
        let mut fields = 0usize;
        for line in text.lines() {
            let v = Value::parse(line).unwrap();
            fields += v.as_obj().unwrap().len();
        }
        std::hint::black_box(fields);
    });
}
