//! Serve-daemon overhead (DESIGN.md §16): submit→complete latency and
//! end-to-end jobs/sec through the full daemon stack — wire protocol,
//! durable-queue journaling, dispatch, per-tenant store append, and the
//! result stream — at queue depths 1, 8, and 64. Sweeps run synthetically
//! (`SLIMADAM_SYNTH_RUNS`) with zero per-job compute, so the rates
//! isolate the service machinery itself. Writes the consolidated
//! `results/bench/BENCH_serve.json` summary and gates it against the
//! committed `BENCH_serve_baseline.json` like the native suite.

use std::time::{Duration, Instant};

use slimadam::benchkit::{check_native_regression, write_suite_summary};
use slimadam::json::Value;
use slimadam::serve::{spawn, Client, JobSpec, ServeOpts, ServerHandle};

fn median_ms(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// One single-config job, unique per `i` so nothing resume-skips.
fn spec_for(i: usize) -> JobSpec {
    JobSpec::native("mlp_tiny", &["adam"], &[1e-4 * (1.0 + i as f64 * 1e-3)], 8)
}

fn fresh_daemon(tag: &str) -> (ServerHandle, String) {
    let dir = std::env::temp_dir().join(format!(
        "slimadam_bench_serve_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.join("serve.sock").to_str().unwrap().to_string();
    let handle = spawn(ServeOpts {
        addr: addr.clone(),
        state_dir: dir.join("state"),
        workers: 4,
        max_batch: 8,
        queue_cap: 128,
        quiet: true,
    })
    .expect("spawn serve daemon");
    (handle, addr)
}

fn shutdown(mut client: Client, handle: ServerHandle) {
    client.drain().expect("drain");
    drop(client);
    handle.join().expect("daemon exit");
}

/// Submit `depth` jobs back to back, then wait for the whole backlog —
/// jobs/sec through journal + dispatch + store at that queue depth.
fn throughput(depth: usize) -> f64 {
    let (handle, addr) = fresh_daemon(&format!("depth{depth}"));
    let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let t0 = Instant::now();
    for i in 0..depth {
        let reply = client.submit("bench", &spec_for(i), false).unwrap();
        assert_eq!(
            reply.get("reply").unwrap().as_str().unwrap(),
            "queued",
            "submit {i} rejected: {}",
            reply.dump()
        );
    }
    loop {
        let st = client.status().unwrap();
        let jobs = st.get("jobs").unwrap().as_arr().unwrap();
        let failed = jobs
            .iter()
            .filter(|e| e.get("state").unwrap().as_str().unwrap() == "failed")
            .count();
        assert_eq!(failed, 0, "bench jobs must not fail");
        let done = jobs
            .iter()
            .filter(|e| e.get("state").unwrap().as_str().unwrap() == "done")
            .count();
        if done >= depth {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let rate = depth as f64 / t0.elapsed().as_secs_f64();
    shutdown(client, handle);
    println!("serve depth {depth:>3}: {rate:9.1} jobs/s");
    rate
}

fn main() {
    // synthetic, zero-latency jobs: the numbers are pure serve overhead
    std::env::set_var("SLIMADAM_SYNTH_RUNS", "1");
    std::env::remove_var("SLIMADAM_SYNTH_MS");
    let fast = std::env::var("SLIMADAM_BENCH_FAST").is_ok();

    // submit→complete latency, one watched job at a time
    let iters = if fast { 8 } else { 30 };
    let (handle, addr) = fresh_daemon("latency");
    let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let mut lat_ms = Vec::with_capacity(iters);
    let t0 = Instant::now();
    for i in 0..iters {
        let t = Instant::now();
        let reply = client.submit("bench", &spec_for(i), true).unwrap();
        let job = reply.get("job").unwrap().as_str().unwrap().to_string();
        client.wait_job(&job, |_| {}).unwrap();
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let depth1_rate = iters as f64 / t0.elapsed().as_secs_f64();
    shutdown(client, handle);
    let lat = median_ms(lat_ms);
    println!("serve submit→complete: {lat:.2} ms median ({iters} watched jobs)");
    println!("serve depth   1: {depth1_rate:9.1} jobs/s");

    let depth8_rate = throughput(8);
    let depth64_rate = throughput(64);

    let mut row = Value::obj();
    row.set("model", "serve")
        .set("workers", 4usize)
        .set("serve_submit_complete_ms", lat)
        .set("serve_jobs_per_s_depth1", depth1_rate)
        .set("serve_jobs_per_s_depth8", depth8_rate)
        .set("serve_jobs_per_s_depth64", depth64_rate);

    let out = std::path::Path::new("results/bench/BENCH_serve.json");
    write_suite_summary("serve", &[row], out).expect("write BENCH_serve.json");
    println!("\nwrote serve throughput summary to {}", out.display());

    // Baseline gate (CI `bench-regression`): same mechanics as the native
    // suite — a provisional baseline only warns.
    let baseline_path = std::env::var("SLIMADAM_BENCH_SERVE_BASELINE")
        .unwrap_or_else(|_| "results/bench/BENCH_serve_baseline.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let baseline = Value::parse(&text).expect("parse serve baseline");
            let current =
                Value::parse(&std::fs::read_to_string(out).unwrap()).expect("parse summary");
            let outcome = check_native_regression(&baseline, &current, 0.15);
            for w in &outcome.warnings {
                println!("bench-regression warning: {w}");
            }
            if !outcome.passed() {
                for v in &outcome.violations {
                    eprintln!("bench-regression FAIL: {v}");
                }
                std::process::exit(1);
            }
            println!(
                "bench-regression: ok vs {baseline_path} ({} warnings)",
                outcome.warnings.len()
            );
        }
        Err(_) => println!(
            "bench-regression: no baseline at {baseline_path} (commit \
             results/bench/BENCH_serve_baseline.json to arm the gate)"
        ),
    }
}
