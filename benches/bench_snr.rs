//! SNR computation (Eq. 3) throughput — the probe must be cheap enough to
//! run at the paper's cadence without perturbing training wallclock.

use slimadam::benchkit::Bencher;
use slimadam::runtime::KMode;
use slimadam::snr::snr_of_view;

fn main() {
    let b = Bencher::default();
    println!("== SNR_K throughput ==");
    for (rows, cols) in [(64usize, 64usize), (512, 512), (768, 3072)] {
        let mut rng = slimadam::rng::Rng::new(2);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| (rng.normal().abs() + 1e-4) as f32)
            .collect();
        for k in [KMode::FanOut, KMode::FanIn, KMode::Both] {
            b.bench_with_units(
                &format!("snr/{}x{}/{}", rows, cols, k.as_str()),
                (rows * cols) as f64,
                "elem",
                || {
                    std::hint::black_box(snr_of_view(rows, cols, &data, k));
                },
            );
        }
    }

    // full-probe cost on a gpt_nano-shaped model
    if let Ok(man) = slimadam::runtime::Manifest::load("artifacts/gpt_nano.grad.manifest.json") {
        use slimadam::optim::adamk::AdamK;
        use slimadam::optim::{KMode as K, Optimizer};
        use slimadam::snr::SnrProbe;
        use slimadam::tensor::Tensor;
        let mut rng = slimadam::rng::Rng::new(3);
        let mut opt = AdamK::new(
            "adam",
            man.params.clone(),
            vec![K::None; man.n_params()],
            Default::default(),
        );
        let mut params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let grads: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| {
                Tensor::from_vec(
                    &p.shape,
                    (0..p.numel()).map(|_| rng.normal() as f32).collect(),
                )
            })
            .collect();
        opt.step(&mut params, &grads, 1, 1e-4);
        let b2 = Bencher::default();
        b2.bench_with_units(
            "snr/full_probe/gpt_nano",
            man.total_param_elems() as f64,
            "param",
            || {
                let mut probe = SnrProbe::new();
                probe.record(1, &opt, &man.params);
                std::hint::black_box(&probe);
            },
        );
    }
}
