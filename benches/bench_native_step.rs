//! Native-backend step latency (DESIGN.md §11, §13): grad_step and fused
//! train_step throughput of the pure-Rust interpreter for every builtin
//! model family — MLP, one- and four-block transformers, and the conv
//! classifier — plus the full split-path step (grads + clip + AdamK
//! update). Unlike the PJRT benches this needs no artifacts, so it always
//! runs — the regression guard for the interpreter's forward/backward
//! passes. At the end it writes the consolidated per-family throughput
//! summary `results/bench/BENCH_native.json` (the CI bench artifact).

use slimadam::benchkit::{check_native_regression, write_native_summary, Bencher};
use slimadam::coordinator::{make_data, DataSpec};
use slimadam::json::Value;
use slimadam::optim::adamk::{effective_k, AdamK};
use slimadam::optim::{clip_global_norm, KMode, Optimizer};
use slimadam::rules::adaptive::{AdaptivePolicy, Controller};
use slimadam::snr::snr_of_view;
use slimadam::runtime::backend::native::KernelMode;
use slimadam::runtime::backend::{backend_for, native, Backend, BackendSpec};
use slimadam::runtime::engine::{GradEngine, TrainEngine};
use slimadam::tensor::Tensor;

fn main() {
    let backend = backend_for(&BackendSpec::native()).expect("native backend");
    let backend_f32 = backend_for(&BackendSpec::native_f32()).expect("native+f32 backend");
    let b = Bencher::default();
    let mut summary_rows: Vec<Value> = Vec::new();

    for &model in native::MODELS {
        let engine = GradEngine::new("artifacts", model, backend.as_ref())
            .expect("native grad engine");
        let man = engine.manifest().clone();
        // throughput unit: tokens for the LM families, samples for conv
        let (units, unit_label): (f64, &'static str) = if man.batch[0].dtype == "f32" {
            (man.batch_size() as f64, "sample")
        } else {
            (man.batch[0].shape.iter().product::<usize>() as f64, "tok")
        };
        let mut rng = slimadam::rng::Rng::new(4);
        let mut params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let mut data = make_data(&man, &DataSpec::default_for(&man), 11).unwrap();
        let batch = data.next_batch();

        println!("== {model}: native grad_step ==");
        let grad_report =
            b.bench_with_units(&format!("native/{model}/grad_step"), units, unit_label, || {
                let (_loss, _grads) = engine.step(&params, &batch).unwrap();
            });

        let mut opt = AdamK::new(
            "adam",
            man.params.clone(),
            vec![KMode::None; man.n_params()],
            Default::default(),
        );
        let mut t = 0usize;
        let split_report = b.bench_with_units(
            &format!("native/{model}/split_full_step"),
            units,
            unit_label,
            || {
                t += 1;
                let (_loss, mut grads) = engine.step(&params, &batch).unwrap();
                clip_global_norm(&mut grads, 1.0);
                opt.step(&mut params, &grads, t, 1e-4);
            },
        );

        // every fused engine variant: the AdamW rulesets plus the
        // bake-off optimizer kernels (Lion, SGDM, SM3, Adafactor,
        // rank-4 factored V) — one `fused_step/<token>` row each
        let mut fused_adam_report = None;
        let mut fused_slim_report = None;
        for &ruleset in native::RULESETS.iter().chain(native::OPTIMIZERS) {
            let mut fused =
                TrainEngine::new("artifacts", model, ruleset, backend.as_ref(), "mitchell", 5)
                    .expect("native fused engine");
            println!("== {model}: native fused train_step ({ruleset}) ==");
            let report = b.bench_with_units(
                &format!("native/{model}/fused_step/{ruleset}"),
                units,
                unit_label,
                || {
                    fused.step(&batch, 1e-4).unwrap();
                },
            );
            if ruleset == "adam" {
                fused_adam_report = Some(report);
            } else if ruleset == "slimadam" {
                fused_slim_report = Some(report);
            }
        }

        // Self-tuning controller overhead (DESIGN.md §18): the fused
        // slimadam step with the SNR controller evaluating every step —
        // worst-case telemetry cadence, never-fire thresholds, so no
        // migrations run and the row isolates the pure eval cost
        // (first-moment read + SNR of m² per ruled tensor).
        let mut fused_adaptive =
            TrainEngine::new("artifacts", model, "slimadam", backend.as_ref(), "mitchell", 5)
                .expect("native fused engine");
        let aman = fused_adaptive.manifest().clone();
        let targets = aman.k_modes.clone().expect("slimadam artifact bakes k_modes");
        let mut policy = AdaptivePolicy::never_fire();
        policy.every = 1;
        let mut ctl = Controller::slim_start(
            policy,
            aman.params.iter().map(|p| p.name.clone()).collect(),
            targets.clone(),
        );
        let mut at = 0usize;
        println!("== {model}: fused train_step + adaptive SNR eval ==");
        let adaptive_report = b.bench_with_units(
            &format!("native/{model}/fused_step_adaptive"),
            units,
            unit_label,
            || {
                at += 1;
                fused_adaptive.step(&batch, 1e-4).unwrap();
                let ms = fused_adaptive.first_moments().unwrap();
                let snrs: Vec<f64> = ms
                    .iter()
                    .enumerate()
                    .map(|(i, m)| {
                        if ctl.is_inert(i) {
                            return f64::NAN;
                        }
                        let info = &aman.params[i];
                        let m2 = Tensor::from_vec(
                            &info.shape,
                            m.data.iter().map(|&x| x * x).collect(),
                        );
                        let view = m2.matrix_view(info.fan_out_axis);
                        snr_of_view(
                            view.rows,
                            view.cols,
                            &view.data,
                            effective_k(info, targets[i]),
                        )
                    })
                    .collect();
                let fired = ctl.observe(at, &snrs);
                assert!(fired.is_empty(), "never-fire policy must not migrate");
            },
        );

        // Flight-recorder overhead (DESIGN.md §15): the identical fused
        // step with span tracing live. The enabled path adds clock reads
        // + ring pushes per kernel section; the gate at the bottom holds
        // it to ≤ 5% over the untraced rate.
        let trace_dir = std::env::temp_dir().join(format!(
            "slimadam_bench_trace_{}",
            std::process::id()
        ));
        slimadam::obs::start_tracing(&trace_dir).expect("start tracing");
        let mut fused_traced =
            TrainEngine::new("artifacts", model, "adam", backend.as_ref(), "mitchell", 5)
                .expect("native fused engine");
        println!("== {model}: fused train_step, tracing live ==");
        let traced_report = b.bench_with_units(
            &format!("native/{model}/fused_step_traced"),
            units,
            unit_label,
            || {
                fused_traced.step(&batch, 1e-4).unwrap();
            },
        );
        slimadam::obs::stop_tracing().expect("stop tracing");

        // Pre-PR scalar kernels (ISSUE 6 acceptance: the SIMD fused step
        // must show ≥ 2× over this on gpt_deep). ScalarRef swaps every
        // reassociating kernel back to its scalar-order oracle body and
        // forces intra-op workers to 1, so this measures exactly the old
        // hot path on the same build.
        let mut fused_scalar =
            TrainEngine::new("artifacts", model, "adam", backend.as_ref(), "mitchell", 5)
                .expect("native fused engine");
        println!("== {model}: fused train_step, scalar-reference kernels ==");
        native::set_kernel_mode(KernelMode::ScalarRef);
        let scalar_report = b.bench_with_units(
            &format!("native/{model}/fused_step_scalar_ref"),
            units,
            unit_label,
            || {
                fused_scalar.step(&batch, 1e-4).unwrap();
            },
        );
        native::set_kernel_mode(KernelMode::Simd);

        // Opt-in f32 compute mode (DESIGN.md §14): same kernels
        // instantiated at f32.
        let mut fused_f32 =
            TrainEngine::new("artifacts", model, "adam", backend_f32.as_ref(), "mitchell", 5)
                .expect("native+f32 fused engine");
        println!("== {model}: fused train_step, f32 compute ==");
        let f32_report = b.bench_with_units(
            &format!("native/{model}/fused_step_f32"),
            units,
            unit_label,
            || {
                fused_f32.step(&batch, 1e-4).unwrap();
            },
        );

        // Batched lockstep dispatch (DESIGN.md §12): LANES fused jobs per
        // run_batch call vs the same jobs stepped one at a time — the
        // per-step half of the batched-vs-sequential comparison
        // (bench_sweep.rs measures the whole sweep path).
        const LANES: usize = 4;
        let art = backend
            .load_artifact(std::path::Path::new("artifacts"), &format!("{model}.train.adam"))
            .expect("native train artifact");
        let compiled = std::rc::Rc::new(art.compile(backend.as_ref()).expect("compile"));
        let batches: Vec<_> = (0..LANES).map(|_| batch.clone()).collect();
        let lrs = [1e-4f32; LANES];

        let mut solo: Vec<TrainEngine> = (0..LANES)
            .map(|i| {
                TrainEngine::with_compiled(compiled.clone(), "mitchell", 50 + i as u64).unwrap()
            })
            .collect();
        println!("== {model}: sequential vs batched fused dispatch ({LANES} jobs) ==");
        let seq_report = b.bench_with_units(
            &format!("native/{model}/fused_step_seq{LANES}"),
            units * LANES as f64,
            unit_label,
            || {
                for (e, bt) in solo.iter_mut().zip(&batches) {
                    e.step(bt, 1e-4).unwrap();
                }
            },
        );

        let mut stacked: Vec<TrainEngine> = (0..LANES)
            .map(|i| {
                TrainEngine::with_compiled(compiled.clone(), "mitchell", 50 + i as u64).unwrap()
            })
            .collect();
        let batch_report = b.bench_with_units(
            &format!("native/{model}/fused_step_batch{LANES}"),
            units * LANES as f64,
            unit_label,
            || {
                let mut refs: Vec<&mut TrainEngine> = stacked.iter_mut().collect();
                TrainEngine::step_many(&mut refs, &batches, &lrs).unwrap();
            },
        );

        // per-family row of the consolidated BENCH_native.json artifact
        let step_s = |ns: f64| 1.0 / (ns / 1e9).max(1e-12);
        let mut row = Value::obj();
        row.set("model", model)
            .set("family", man.family.clone())
            .set("params", man.total_param_elems())
            .set("unit", unit_label)
            .set("grad_units_per_s", grad_report.units_per_sec().unwrap_or(0.0))
            .set("split_steps_per_s", step_s(split_report.median_ns))
            .set(
                "fused_steps_per_s",
                fused_adam_report
                    .as_ref()
                    .map(|r| step_s(r.median_ns))
                    .unwrap_or(0.0),
            )
            .set("fused_steps_per_s_scalar_ref", step_s(scalar_report.median_ns))
            .set("fused_steps_per_s_traced", step_s(traced_report.median_ns))
            .set(
                "tracing_overhead",
                traced_report.median_ns
                    / fused_adam_report
                        .as_ref()
                        .map(|r| r.median_ns)
                        .unwrap_or(f64::MAX)
                        .max(1e-12)
                    - 1.0,
            )
            .set("fused_steps_per_s_f32", step_s(f32_report.median_ns))
            .set("adaptive_steps_per_s", step_s(adaptive_report.median_ns))
            .set(
                "adaptive_eval_overhead",
                adaptive_report.median_ns
                    / fused_slim_report
                        .as_ref()
                        .map(|r| r.median_ns)
                        .unwrap_or(f64::MAX)
                        .max(1e-12)
                    - 1.0,
            )
            .set(
                "fused_simd_speedup",
                scalar_report.median_ns
                    / fused_adam_report
                        .as_ref()
                        .map(|r| r.median_ns)
                        .unwrap_or(f64::MAX)
                        .max(1e-12),
            )
            .set(
                "fused_jobs_per_s_seq4",
                LANES as f64 * step_s(seq_report.median_ns),
            )
            .set(
                "fused_jobs_per_s_batch4",
                LANES as f64 * step_s(batch_report.median_ns),
            )
            .set(
                "batch4_speedup",
                seq_report.median_ns / batch_report.median_ns.max(1e-12),
            );
        summary_rows.push(row);
    }

    // traced runs above all shared one per-pid temp sink; drop it now
    let _ = std::fs::remove_dir_all(
        std::env::temp_dir().join(format!("slimadam_bench_trace_{}", std::process::id())),
    );

    let out = std::path::Path::new("results/bench/BENCH_native.json");
    write_native_summary(&summary_rows, out).expect("write BENCH_native.json");
    println!("\nwrote per-family throughput summary to {}", out.display());

    // Tracing-overhead gate (DESIGN.md §15 acceptance): the traced fused
    // step must stay within 5% of the untraced rate for every family.
    let mut trace_fail = false;
    for row in &summary_rows {
        let model = row
            .opt("model")
            .and_then(|v| v.as_str().ok())
            .unwrap_or("?");
        let overhead = row
            .opt("tracing_overhead")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0);
        if overhead > 0.05 {
            eprintln!(
                "tracing-overhead FAIL: {model} fused_step_traced is {:.1}% \
                 slower than fused_step (allowed 5%)",
                100.0 * overhead
            );
            trace_fail = true;
        } else {
            println!(
                "tracing-overhead: {model} {:+.1}% (gate ≤ 5%)",
                100.0 * overhead
            );
        }
    }
    if trace_fail {
        std::process::exit(1);
    }

    // Baseline gate (CI `bench-regression`): compare the summary just
    // written against the committed baseline and fail the process on a
    // > 15% throughput regression. A provisional baseline (the bootstrap
    // commit) only warns — see `benchkit::check_native_regression`.
    let baseline_path = std::env::var("SLIMADAM_BENCH_BASELINE")
        .unwrap_or_else(|_| "results/bench/BENCH_baseline.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let baseline = Value::parse(&text).expect("parse bench baseline");
            let current =
                Value::parse(&std::fs::read_to_string(out).unwrap()).expect("parse summary");
            let outcome = check_native_regression(&baseline, &current, 0.15);
            for w in &outcome.warnings {
                println!("bench-regression warning: {w}");
            }
            if !outcome.passed() {
                for v in &outcome.violations {
                    eprintln!("bench-regression FAIL: {v}");
                }
                std::process::exit(1);
            }
            println!(
                "bench-regression: ok vs {baseline_path} ({} warnings)",
                outcome.warnings.len()
            );
        }
        Err(_) => println!(
            "bench-regression: no baseline at {baseline_path} (set \
             SLIMADAM_BENCH_BASELINE or commit results/bench/BENCH_baseline.json)"
        ),
    }
}
