//! Native-backend step latency (DESIGN.md §11): grad_step and fused
//! train_step throughput of the pure-Rust interpreter for every builtin
//! model, plus the full split-path step (grads + clip + AdamK update).
//! Unlike the PJRT benches this needs no artifacts, so it always runs —
//! the regression guard for the interpreter's forward/backward passes.

use slimadam::benchkit::Bencher;
use slimadam::coordinator::{make_data, DataSpec};
use slimadam::optim::adamk::AdamK;
use slimadam::optim::{clip_global_norm, KMode, Optimizer};
use slimadam::runtime::backend::{backend_for, native, Backend, BackendSpec};
use slimadam::runtime::engine::{GradEngine, TrainEngine};
use slimadam::tensor::Tensor;

fn main() {
    let backend = backend_for(&BackendSpec::native()).expect("native backend");
    let b = Bencher::default();
    let data_spec = DataSpec::Markov {
        alpha: 1.07,
        coherence: 0.5,
        seed: 7,
    };

    for &model in native::MODELS {
        let engine = GradEngine::new("artifacts", model, backend.as_ref())
            .expect("native grad engine");
        let man = engine.manifest().clone();
        let tokens = man.batch[0].shape.iter().product::<usize>() as f64;
        let mut rng = slimadam::rng::Rng::new(4);
        let mut params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let mut data = make_data(&man, &data_spec, 11).unwrap();
        let batch = data.next_batch();

        println!("== {model}: native grad_step ==");
        b.bench_with_units(&format!("native/{model}/grad_step"), tokens, "tok", || {
            let (_loss, _grads) = engine.step(&params, &batch).unwrap();
        });

        let mut opt = AdamK::new(
            "adam",
            man.params.clone(),
            vec![KMode::None; man.n_params()],
            Default::default(),
        );
        let mut t = 0usize;
        b.bench_with_units(
            &format!("native/{model}/split_full_step"),
            tokens,
            "tok",
            || {
                t += 1;
                let (_loss, mut grads) = engine.step(&params, &batch).unwrap();
                clip_global_norm(&mut grads, 1.0);
                opt.step(&mut params, &grads, t, 1e-4);
            },
        );

        for &ruleset in native::RULESETS {
            let mut fused =
                TrainEngine::new("artifacts", model, ruleset, backend.as_ref(), "mitchell", 5)
                    .expect("native fused engine");
            println!("== {model}: native fused train_step ({ruleset}) ==");
            b.bench_with_units(
                &format!("native/{model}/fused_step/{ruleset}"),
                tokens,
                "tok",
                || {
                    fused.step(&batch, 1e-4).unwrap();
                },
            );
        }

        // Batched lockstep dispatch (DESIGN.md §12): LANES fused jobs per
        // run_batch call vs the same jobs stepped one at a time — the
        // per-step half of the batched-vs-sequential comparison
        // (bench_sweep.rs measures the whole sweep path).
        const LANES: usize = 4;
        let art = backend
            .load_artifact(std::path::Path::new("artifacts"), &format!("{model}.train.adam"))
            .expect("native train artifact");
        let compiled = std::rc::Rc::new(art.compile(backend.as_ref()).expect("compile"));
        let batches: Vec<_> = (0..LANES).map(|_| batch.clone()).collect();
        let lrs = [1e-4f32; LANES];

        let mut solo: Vec<TrainEngine> = (0..LANES)
            .map(|i| {
                TrainEngine::with_compiled(compiled.clone(), "mitchell", 50 + i as u64).unwrap()
            })
            .collect();
        println!("== {model}: sequential vs batched fused dispatch ({LANES} jobs) ==");
        b.bench_with_units(
            &format!("native/{model}/fused_step_seq{LANES}"),
            tokens * LANES as f64,
            "tok",
            || {
                for (e, bt) in solo.iter_mut().zip(&batches) {
                    e.step(bt, 1e-4).unwrap();
                }
            },
        );

        let mut stacked: Vec<TrainEngine> = (0..LANES)
            .map(|i| {
                TrainEngine::with_compiled(compiled.clone(), "mitchell", 50 + i as u64).unwrap()
            })
            .collect();
        b.bench_with_units(
            &format!("native/{model}/fused_step_batch{LANES}"),
            tokens * LANES as f64,
            "tok",
            || {
                let mut refs: Vec<&mut TrainEngine> = stacked.iter_mut().collect();
                TrainEngine::step_many(&mut refs, &batches, &lrs).unwrap();
            },
        );
    }
}
