//! Sweep-scheduler throughput: serial vs work-stealing parallel dispatch,
//! and batched vs sequential in-worker dispatch (DESIGN.md §12).
//!
//! The acceptance bar for the parallel scheduler is ≥2x wall-clock
//! speedup at 4 workers on compute-bound jobs; the synthetic section
//! measures exactly that with SNR evaluations sized like a real probe.
//! The batched section runs the builtin-MLP native sweep unbatched vs
//! `--batch`-style stacked dispatch on one worker (isolating the
//! batching win from pool parallelism) and emits jobs/sec comparison
//! JSON into `results/bench/` — the ISSUE 4 acceptance row (≥1.5x
//! native jobs/sec at batch 4). When artifacts exist, the last section
//! times a real 8-point LR sweep serial-vs-parallel and prints the
//! executable-cache counters (each distinct artifact must compile at
//! most once per worker).

use slimadam::benchkit::{bench_batched, bench_sweep};
use slimadam::coordinator::{exec_cache, SweepScheduler, TrainConfig};
use slimadam::runtime::backend::BackendSpec;
use slimadam::runtime::KMode;
use slimadam::snr::snr_of_view;

fn native_grid(model: &str, steps: usize) -> Vec<TrainConfig> {
    let mut configs = Vec::new();
    for opt in ["adam", "slimadam"] {
        for lr in [5e-4, 1e-3, 2e-3, 4e-3] {
            // family-appropriate workload per model (conv gets images)
            let mut cfg = TrainConfig::auto(model, opt, lr, steps);
            cfg.backend = BackendSpec::native();
            cfg.eval_batches = 2;
            configs.push(cfg);
        }
    }
    configs
}

fn main() {
    println!("== batched vs sequential native dispatch (8-job sweeps, 1 worker) ==");
    let fast = std::env::var("SLIMADAM_BENCH_FAST").is_ok();
    // Per-thread executable caches can't be pre-warmed here — the pool
    // spawns fresh worker threads per run() call, so every run pays the
    // same (cheap: manifest generation + a dims check) native compile on
    // its own thread regardless of batching. An untimed pass only warms
    // process-level state (allocator, lazy init) so the timed sequential
    // side, which runs first, isn't systematically colder.
    //
    // mlp_tiny is the batch-scaling row (2/4/8); the rest of the zoo gets
    // one jobs/sec row each at batch 4 — the per-family throughput table
    // EXPERIMENTS.md §Perf tracks.
    let mlp_configs = native_grid("mlp_tiny", if fast { 30 } else { 120 });
    SweepScheduler::new(1)
        .quiet()
        .run(&mlp_configs[..2])
        .expect("warmup");
    for batch in [2usize, 4, 8] {
        bench_batched(
            &format!("sweep_native_batch{batch}"),
            mlp_configs.len(),
            batch,
            Some(std::path::Path::new("results/bench")),
            || {
                SweepScheduler::new(1)
                    .quiet()
                    .run(&mlp_configs)
                    .expect("sequential native sweep");
            },
            || {
                SweepScheduler::new(1)
                    .quiet()
                    .batch(batch)
                    .run(&mlp_configs)
                    .expect("batched native sweep");
            },
        );
    }
    for model in ["gpt_micro", "gpt_deep", "conv_mini"] {
        let configs = native_grid(model, if fast { 10 } else { 40 });
        bench_batched(
            &format!("sweep_native_{model}_batch4"),
            configs.len(),
            4,
            Some(std::path::Path::new("results/bench")),
            || {
                SweepScheduler::new(1)
                    .quiet()
                    .run(&configs)
                    .expect("sequential native sweep");
            },
            || {
                SweepScheduler::new(1)
                    .quiet()
                    .batch(4)
                    .run(&configs)
                    .expect("batched native sweep");
            },
        );
    }

    // Intra-op kernel parallelism (ISSUE 6, DESIGN.md §14): the same
    // single-worker gpt_deep train run with 1 vs 2 intra-op kernel
    // threads. Results are bitwise identical by contract
    // (`scheduler_determinism::intraop_parallel_train_steps_are_worker_count_invariant`);
    // this row tracks what the knob buys in wall-clock.
    println!("\n== intra-op kernel workers, gpt_deep fused (1 job) ==");
    {
        let mut cfg = TrainConfig::auto("gpt_deep", "adam", 1e-3, if fast { 4 } else { 12 });
        cfg.backend = BackendSpec::native();
        cfg.engine = slimadam::coordinator::EngineKind::Fused("adam".to_string());
        cfg.eval_batches = 1;
        let configs = vec![cfg];
        bench_batched(
            "sweep_native_gpt_deep_intraop2",
            1,
            1,
            Some(std::path::Path::new("results/bench")),
            || {
                slimadam::pool::set_intraop_workers(1);
                SweepScheduler::new(1).quiet().run(&configs).expect("intraop 1");
            },
            || {
                slimadam::pool::set_intraop_workers(2);
                SweepScheduler::new(1).quiet().run(&configs).expect("intraop 2");
                slimadam::pool::set_intraop_workers(1);
            },
        );
    }

    println!("\n== synthetic compute-bound sweep jobs (512x512 SNR probes) ==");
    let data: Vec<f32> = (0..512 * 512)
        .map(|i| (i % 97) as f32 * 0.01 + 1.0)
        .collect();
    let cores = slimadam::pool::default_workers(usize::MAX);
    for workers in [2, 4, cores] {
        bench_sweep(&format!("sweep_snr_w{workers}"), 16, workers, |_| {
            for k in [KMode::FanOut, KMode::FanIn, KMode::Both] {
                std::hint::black_box(snr_of_view(512, 512, &data, k));
            }
        });
    }

    if !std::path::Path::new("artifacts/linear2_v64.grad.hlo.txt").exists() {
        println!("(skipping real-artifact sweep: run `make artifacts` first)");
        return;
    }

    println!("\n== real 8-point LR sweep, linear2_v64 ==");
    let configs: Vec<TrainConfig> = (0..8)
        .map(|i| {
            let mut cfg = TrainConfig::lm("linear2_v64", "adam", 1e-3, 12);
            cfg.lr = 1e-3 * (1.0 + 0.2 * i as f64);
            cfg.eval_batches = 2;
            cfg
        })
        .collect();

    exec_cache::reset_stats();
    let t0 = std::time::Instant::now();
    SweepScheduler::new(1)
        .quiet()
        .run(&configs)
        .expect("serial sweep");
    let serial = t0.elapsed().as_secs_f64();
    let serial_stats = exec_cache::stats();

    exec_cache::reset_stats();
    let t1 = std::time::Instant::now();
    SweepScheduler::new(4)
        .quiet()
        .run(&configs)
        .expect("parallel sweep");
    let parallel = t1.elapsed().as_secs_f64();
    let parallel_stats = exec_cache::stats();

    println!(
        "serial   {serial:.2} s  (cache: {} hits / {} compiles)",
        serial_stats.hits,
        serial_stats.compiles()
    );
    println!(
        "parallel {parallel:.2} s  (cache: {} hits / {} compiles)  [{:.2}x]",
        parallel_stats.hits,
        parallel_stats.compiles(),
        serial / parallel.max(1e-12)
    );
}
