//! Optimizer step latency across the family (gpt_nano-shaped parameter
//! list). Regenerates the cost side of the paper's memory/compute
//! trade-off: compressed-K AdamK must not be slower than Adam (it reads
//! and writes less state).

use slimadam::benchkit::Bencher;
use slimadam::optim::presets;
use slimadam::optim::Optimizer;
use slimadam::runtime::Manifest;
use slimadam::tensor::Tensor;

fn main() {
    let man = Manifest::load("artifacts/gpt_nano.grad.manifest.json")
        .expect("run `make artifacts` first");
    let total: usize = man.total_param_elems();
    let mut rng = slimadam::rng::Rng::new(1);
    let mut params: Vec<Tensor> = man
        .params
        .iter()
        .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
        .collect();
    let grads: Vec<Tensor> = man
        .params
        .iter()
        .map(|p| {
            Tensor::from_vec(
                &p.shape,
                (0..p.numel()).map(|_| rng.normal() as f32).collect(),
            )
        })
        .collect();

    let b = Bencher::default();
    println!("== optimizer step latency (gpt_nano, {total} params) ==");
    for name in presets::ALL {
        let mut opt = presets::build(name, &man, Default::default()).unwrap();
        let mut t = 0usize;
        b.bench_with_units(&format!("optim_step/{name}"), total as f64, "param", || {
            t += 1;
            opt.step(&mut params, &grads, t, 1e-4);
        });
    }
}
