//! PJRT engine step latency: split grad_step vs fused train_step, plus the
//! host↔literal conversion overhead the fused path avoids. This is the
//! per-step cost decomposition behind EXPERIMENTS.md §Perf.

use slimadam::benchkit::Bencher;
use slimadam::coordinator::{make_data, DataSpec};
use slimadam::optim::adamk::AdamK;
use slimadam::optim::{clip_global_norm, KMode, Optimizer};
use slimadam::runtime::backend::{backend_for, BackendSpec};
use slimadam::runtime::engine::{GradEngine, TrainEngine};
use slimadam::runtime::literal::{literal_to_tensor, tensor_to_literal};
use slimadam::tensor::Tensor;

fn main() {
    let Ok(backend) = backend_for(&BackendSpec::pjrt()) else {
        eprintln!("skipping: pjrt backend not compiled in (use --features pjrt)");
        return;
    };
    let b = Bencher::default();
    let data_spec = DataSpec::Markov {
        alpha: 1.07,
        coherence: 0.5,
        seed: 7,
    };

    for model in ["gpt_nano", "gpt_mini"] {
        let Ok(engine) = GradEngine::new("artifacts", model, backend.as_ref()) else {
            eprintln!("skipping {model}: artifacts missing");
            continue;
        };
        let man = engine.manifest().clone();
        let tokens = man.batch[0].shape.iter().product::<usize>() as f64;
        let mut rng = slimadam::rng::Rng::new(4);
        let mut params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let mut data = make_data(&man, &data_spec, 11).unwrap();
        let batch = data.next_batch();

        println!("== {model}: split engine ==");
        b.bench_with_units(&format!("engine/{model}/grad_step"), tokens, "tok", || {
            let (_loss, _grads) = engine.step(&params, &batch).unwrap();
        });

        let mut opt = AdamK::new(
            "adam",
            man.params.clone(),
            vec![KMode::None; man.n_params()],
            Default::default(),
        );
        let mut t = 0usize;
        b.bench_with_units(
            &format!("engine/{model}/split_full_step"),
            tokens,
            "tok",
            || {
                t += 1;
                let (_loss, mut grads) = engine.step(&params, &batch).unwrap();
                clip_global_norm(&mut grads, 1.0);
                opt.step(&mut params, &grads, t, 1e-4);
            },
        );

        // literal conversion overhead (params up + grads down)
        b.bench(&format!("engine/{model}/literal_upload"), || {
            for p in &params {
                std::hint::black_box(tensor_to_literal(p).unwrap());
            }
        });
        let lits: Vec<_> = params.iter().map(|p| tensor_to_literal(p).unwrap()).collect();
        b.bench(&format!("engine/{model}/literal_download"), || {
            for l in &lits {
                std::hint::black_box(literal_to_tensor(l).unwrap());
            }
        });

        // fused engine (artifact exists for gpt_nano/gpt_mini adam+slimadam)
        for ruleset in ["adam", "slimadam"] {
            let Ok(mut fused) =
                TrainEngine::new("artifacts", model, ruleset, backend.as_ref(), "mitchell", 5)
            else {
                continue;
            };
            println!("== {model}: fused engine ({ruleset}) ==");
            b.bench_with_units(
                &format!("engine/{model}/fused_step/{ruleset}"),
                tokens,
                "tok",
                || {
                    fused.step(&batch, 1e-4).unwrap();
                },
            );
        }
    }
}
