//! Data-pipeline throughput: the batch generators must never bottleneck
//! the PJRT step (they run on the same thread in the training loop).

use slimadam::benchkit::Bencher;
use slimadam::data::bpe::Bpe;
use slimadam::data::images::SynthImages;
use slimadam::data::markov::MarkovLm;
use slimadam::data::DataSource;

fn main() {
    let b = Bencher::default();
    println!("== data pipeline throughput ==");

    // Markov LM batches (gpt_nano geometry)
    let mut lm = MarkovLm::new(512, 1.07, 0.5, 1).source(16, 64, 2);
    b.bench_with_units("data/markov_batch_16x64", (16 * 64) as f64, "tok", || {
        std::hint::black_box(lm.next_batch());
    });

    // gpt_mini geometry
    let mut lm2 = MarkovLm::new(2048, 1.07, 0.5, 1).source(8, 128, 2);
    b.bench_with_units("data/markov_batch_8x128", (8 * 128) as f64, "tok", || {
        std::hint::black_box(lm2.next_batch());
    });

    // synthetic images (vit/resnet geometry)
    let mut imgs = SynthImages::new(100, 32, 3, 0.3, 3).source(32, 4);
    b.bench_with_units("data/images_batch_32x32x32x3", 32.0, "img", || {
        std::hint::black_box(imgs.next_batch());
    });

    // BPE train + encode on repo text
    if let Ok(text) = slimadam::data::corpus::collect_text(".") {
        let sample = &text[..text.len().min(60_000)];
        b.bench_with_units("data/bpe_train_60k_v512", sample.len() as f64, "byte", || {
            std::hint::black_box(Bpe::train(sample, 512));
        });
        let bpe = Bpe::train(sample, 512);
        let probe = &text[..text.len().min(100_000)];
        b.bench_with_units("data/bpe_encode_100k", probe.len() as f64, "byte", || {
            std::hint::black_box(bpe.encode(probe));
        });
    }
}
