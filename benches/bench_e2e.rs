//! End-to-end per-step training throughput for the paper-table workloads
//! (one row per figure-level configuration): the criterion-style numbers
//! EXPERIMENTS.md quotes as the testbed's capability, and the regression
//! guard for the optimization pass (§Perf).

use slimadam::benchkit::Bencher;
use slimadam::coordinator::{make_data, DataSpec};
use slimadam::optim::{clip_global_norm, presets, Hypers};
use slimadam::runtime::backend::{backend_for, BackendSpec};
use slimadam::runtime::engine::GradEngine;
use slimadam::tensor::Tensor;

fn main() {
    let Ok(backend) = backend_for(&BackendSpec::pjrt()) else {
        eprintln!("skipping: pjrt backend not compiled in (use --features pjrt)");
        return;
    };
    let b = Bencher::default();
    println!("== end-to-end step throughput per paper workload ==");

    // (bench id / paper artifact, model, optimizer, data)
    let rows: &[(&str, &str, &str, DataSpec)] = &[
        ("fig1_gpt_adam", "gpt_nano", "adam",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
        ("fig1_gpt_slimadam", "gpt_nano", "slimadam",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
        ("fig1_gpt_adam_mini", "gpt_nano", "adam_mini_v2",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
        ("fig1_gpt_sm3", "gpt_nano", "sm3",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
        ("fig5_resnet_adam", "resnet_mini_c10", "adam",
         DataSpec::Images { noise: 0.3, seed: 9 }),
        ("fig6_vit_adam", "vit_mini_c10", "adam",
         DataSpec::Images { noise: 0.3, seed: 9 }),
        ("fig7_linear2_adam", "linear2_v1024", "adam",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
        ("fig11_gptmini_slimadam", "gpt_mini", "slimadam",
         DataSpec::Markov { alpha: 1.07, coherence: 0.5, seed: 7 }),
    ];

    for (id, model, opt_name, data_spec) in rows {
        let Ok(engine) = GradEngine::new("artifacts", model, backend.as_ref()) else {
            eprintln!("skipping {id}: {model} artifact missing");
            continue;
        };
        let man = engine.manifest().clone();
        let mut rng = slimadam::rng::Rng::new(6);
        let mut params: Vec<Tensor> = man
            .params
            .iter()
            .map(|p| p.init_mitchell.materialize(&p.shape, &mut rng))
            .collect();
        let mut opt = presets::build(opt_name, &man, Hypers::default()).unwrap();
        let mut data = make_data(&man, data_spec, 13).unwrap();
        let units = man.batch[0].shape.iter().product::<usize>() as f64;
        let unit_label: &'static str =
            if matches!(data_spec, DataSpec::Images { .. }) { "px" } else { "tok" };
        let mut t = 0usize;
        b.bench_with_units(&format!("e2e/{id}"), units, unit_label, || {
            t += 1;
            let batch = data.next_batch();
            let (_loss, mut grads) = engine.step(&params, &batch).unwrap();
            clip_global_norm(&mut grads, 1.0);
            opt.step(&mut params, &grads, t, 1e-4);
        });
    }
}
