//! Vendored offline stub of the `xla` PJRT bindings.
//!
//! This repo builds without network access or the `xla_extension` C++
//! runtime, so the binding crate is vendored as a path dependency with
//! the exact API surface the coordinator uses (DESIGN.md §2). Two tiers:
//!
//! * **Host containers are fully functional.** [`Literal`] really stores
//!   f32 / i32 arrays with shapes, so the conversion layer
//!   (`runtime::literal`) and its tests run unmodified.
//! * **Compilation and execution are stubbed.** [`HloModuleProto::from_text_file`],
//!   [`PjRtClient::compile`] and [`PjRtLoadedExecutable::execute`] return a
//!   descriptive error. Swapping this crate for the real bindings (plus
//!   `make artifacts`) lights up the full training path; no coordinator
//!   code changes.
//!
//! Threading contract: the real PJRT wrapper types are not `Send`, and the
//! coordinator's per-worker client/cache architecture depends on that. The
//! stub types carry a `PhantomData<*const ()>` marker so the compiler
//! enforces the same constraint in offline builds.

use std::fmt;
use std::marker::PhantomData;

/// Error type for all stubbed and functional operations.
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: {what} requires the PJRT backend (xla_extension); \
             this build vendors the offline stub — see DESIGN.md §2"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker making a type `!Send`/`!Sync`, matching the real bindings.
type NotThreadSafe = PhantomData<*const ()>;

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: an element buffer plus a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types storable in a [`Literal`] (f32 and i32, the two the
/// artifact manifests use).
pub trait NativeType: Copy + sealed::Sealed {
    #[doc(hidden)]
    fn make_literal(v: Vec<Self>, dims: Vec<i64>) -> Literal;
    #[doc(hidden)]
    fn read_literal(l: &Literal) -> Result<Vec<Self>>;
    #[doc(hidden)]
    fn refill_literal(l: &mut Literal, src: &[Self]) -> Result<()>;
}

impl NativeType for f32 {
    fn make_literal(v: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal {
            dims,
            payload: Payload::F32(v),
        }
    }

    fn read_literal(l: &Literal) -> Result<Vec<f32>> {
        match &l.payload {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error("f32 read of a non-f32 literal".into())),
        }
    }

    fn refill_literal(l: &mut Literal, src: &[f32]) -> Result<()> {
        match &mut l.payload {
            Payload::F32(v) if v.len() == src.len() => {
                v.copy_from_slice(src);
                Ok(())
            }
            _ => Err(Error("copy_raw_from: dtype or length mismatch".into())),
        }
    }
}

impl NativeType for i32 {
    fn make_literal(v: Vec<i32>, dims: Vec<i64>) -> Literal {
        Literal {
            dims,
            payload: Payload::I32(v),
        }
    }

    fn read_literal(l: &Literal) -> Result<Vec<i32>> {
        match &l.payload {
            Payload::I32(v) => Ok(v.clone()),
            _ => Err(Error("i32 read of a non-i32 literal".into())),
        }
    }

    fn refill_literal(l: &mut Literal, src: &[i32]) -> Result<()> {
        match &mut l.payload {
            Payload::I32(v) if v.len() == src.len() => {
                v.copy_from_slice(src);
                Ok(())
            }
            _ => Err(Error("copy_raw_from: dtype or length mismatch".into())),
        }
    }
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::make_literal(v.to_vec(), vec![v.len() as i64])
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::make_literal(vec![v], Vec::new())
    }

    /// Tuple literal (what executables return as their single output).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            payload: Payload::Tuple(elements),
        }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Same buffer, new shape; errors when element counts differ.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            payload: self.payload.clone(),
        })
    }

    /// Array shape (dims); errors on tuple literals.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.payload {
            Payload::Tuple(_) => Err(Error("array_shape of a tuple literal".into())),
            _ => Ok(ArrayShape {
                dims: self.dims.clone(),
            }),
        }
    }

    /// Copy the element buffer out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
    }

    /// In-place refill of the element buffer (no reallocation).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        T::refill_literal(self, src)
    }

    /// First element (the scalar read used for loss / grad-norm outputs).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read_literal(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("get_first_element of an empty literal".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple of a non-tuple literal".into())),
        }
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module. The stub cannot parse HLO text; loading errors out.
pub struct HloModuleProto {
    _marker: NotThreadSafe,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("parsing HLO text"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _marker: NotThreadSafe,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _marker: PhantomData,
        }
    }
}

/// A PJRT client. The stub constructs (so worker pools can stand up),
/// but compilation errors out.
pub struct PjRtClient {
    _marker: NotThreadSafe,
}

impl PjRtClient {
    /// CPU client. Cheap in the real bindings too, which is why every
    /// sweep worker owns one instead of sharing (the types are not `Send`).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            _marker: PhantomData,
        })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compile"))
    }
}

/// A compiled executable resident on a client.
pub struct PjRtLoadedExecutable {
    _marker: NotThreadSafe,
}

impl PjRtLoadedExecutable {
    /// Execute with one argument list; returns per-device output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _marker: NotThreadSafe,
}

impl PjRtBuffer {
    /// Synchronously copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn literal_refill_and_scalar() {
        let mut l = Literal::vec1(&[0i32; 4]);
        l.copy_raw_from(&[7i32, 8, 9, 10]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8, 9, 10]);
        assert!(l.copy_raw_from(&[1i32]).is_err());
        assert_eq!(Literal::scalar(2.5f32).get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[1i32, 2])]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(parts[1].to_tuple().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let l = Literal::vec1(&[1.0f32]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn stubbed_paths_error_helpfully() {
        let e = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(format!("{e}").contains("stub"), "{e}");
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation {
            _marker: PhantomData,
        };
        assert!(client.compile(&comp).is_err());
    }
}
