//! Vendored offline stand-in for the `anyhow` crate.
//!
//! This repo builds without network access, so its two external
//! dependencies are vendored as path crates (DESIGN.md §2). This one
//! provides the subset of `anyhow` the codebase uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters to callers:
//!
//! * `{e}` (Display) prints the outermost message only;
//! * `{e:#}` (alternate) prints the whole `outer: ...: root` chain;
//! * `.context(..)` / `.with_context(..)` push a new outermost message;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// Crate-default result type: `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error carrying its context chain, outermost first.
///
/// Unlike upstream `anyhow::Error` this does not preserve the source
/// error's type for downcasting — nothing in this repo downcasts — but
/// the Display / alternate-Display contract is the same.
pub struct Error {
    /// Never empty; `chain[0]` is the outermost context.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push `context` as the new outermost message.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    fn render_chain(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.render_chain())
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render_chain())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Internal conversion trait so [`Context`] accepts both plain
/// `std::error::Error` values and already-wrapped [`Error`]s (the same
/// coherence trick upstream `anyhow` uses: `Error` itself does not
/// implement `std::error::Error`, so the two impls never overlap).
trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with an outermost context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_vs_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
    }

    #[test]
    fn context_on_anyhow_error_chains() {
        let e: Error = Err::<(), _>(Error::msg("root"))
            .with_context(|| format!("step {}", 2))
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: step 2: root");
    }

    #[test]
    fn option_context() {
        let e = None::<u8>.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros() {
        fn inner(x: u8) -> Result<u8> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 7 {
                bail!("sevens are right out");
            }
            Ok(x)
        }
        assert_eq!(inner(1).unwrap(), 1);
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        assert!(format!("{}", inner(5).unwrap_err()).contains("x != 5"));
        assert!(inner(7).is_err());
        let e = anyhow!("literal only");
        assert_eq!(format!("{e}"), "literal only");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("disk on fire"));
    }
}
