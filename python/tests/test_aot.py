"""AOT lowering pipeline: manifest schema, HLO text sanity, registry
coverage, fixture determinism. These pin the Python→Rust contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.aot import (FUSED, GRAD_MODELS, LM_HYPERS, build_model,
                         lower_grad_step, lower_train_step, to_hlo_text)
from compile.optim_jax import Hypers

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_registry_models_all_buildable():
    for name in GRAD_MODELS:
        model = build_model(name)
        assert model.name == name
        assert len(model.specs) > 0


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        build_model("nope_model")


def test_grad_manifest_schema():
    model = build_model("linear2_v64")
    text, man = lower_grad_step(model)
    # HLO text structure
    assert "HloModule" in text
    assert "ROOT" in text
    # manifest structure
    assert man["kind"] == "grad_step"
    assert len(man["inputs"]) == len(model.specs) + len(model.batch_specs)
    assert len(man["outputs"]) == 1 + len(model.specs)
    assert man["outputs"][0] == "loss"
    for p in man["params"]:
        assert set(p) >= {"name", "shape", "layer_type", "depth",
                          "init_mitchell", "init_default", "wd",
                          "fan_out_axis"}
    # JSON-serializable
    json.dumps(man)


def test_train_manifest_schema():
    model = build_model("linear2_v64")
    text, man = lower_train_step(model, "slimadam", LM_HYPERS)
    n = len(model.specs)
    assert man["kind"] == "train_step"
    assert len(man["inputs"]) == 3 * n + len(model.batch_specs) + 2
    assert len(man["outputs"]) == 2 + 3 * n
    assert len(man["k_modes"]) == n
    assert len(man["v_shapes"]) == n
    assert man["hypers"]["beta2"] == LM_HYPERS.beta2
    json.dumps(man)


def test_hlo_parameter_count_matches_manifest():
    model = build_model("linear2_v64")
    text, man = lower_grad_step(model)
    # every input appears as an HLO entry parameter
    n_params = text.count("parameter(")
    assert n_params >= len(man["inputs"])


def test_no_float64_in_lowered_hlo():
    """CPU perf guard: nothing should silently upcast to f64."""
    model = build_model("gpt_nano")
    text, _ = lower_grad_step(model)
    assert "f64" not in text


def test_existing_artifacts_match_checksums():
    """If `make artifacts` ran, the manifests' recorded sha256 must match
    the on-disk HLO text (guards against stale artifacts)."""
    import hashlib
    if not os.path.isdir(ARTIFACTS):
        pytest.skip("artifacts not built")
    checked = 0
    for fn in os.listdir(ARTIFACTS):
        if not fn.endswith(".manifest.json"):
            continue
        with open(os.path.join(ARTIFACTS, fn)) as f:
            man = json.load(f)
        hlo_path = os.path.join(ARTIFACTS, fn.replace(".manifest.json", ".hlo.txt"))
        with open(hlo_path) as f:
            digest = hashlib.sha256(f.read().encode()).hexdigest()
        assert digest == man["hlo_sha256"], fn
        checked += 1
    assert checked >= len(GRAD_MODELS)


def test_fused_registry_consistency():
    for (name, ruleset) in FUSED:
        assert name in GRAD_MODELS
        assert ruleset in ("adam", "slimadam", "adalayer", "adalayer_ln_tl")


def test_fixture_reference_deterministic(tmp_path):
    """Two runs of the fixture generator must agree exactly."""
    aot.make_fixture(str(tmp_path), "linear2_v64", steps=2, lr=1e-3)
    with open(tmp_path / "fixtures" / "linear2_v64.fixture.json") as f:
        a = json.load(f)
    aot.make_fixture(str(tmp_path), "linear2_v64", steps=2, lr=1e-3)
    with open(tmp_path / "fixtures" / "linear2_v64.fixture.json") as f:
        b = json.load(f)
    assert a == b


def test_fixture_losses_decrease_or_flat():
    if not os.path.isdir(os.path.join(ARTIFACTS, "fixtures")):
        pytest.skip("fixtures not built")
    with open(os.path.join(ARTIFACTS, "fixtures", "linear2_v64.fixture.json")) as f:
        fix = json.load(f)
    losses = fix["losses"]
    assert losses[-1] < losses[0] + 0.1  # random batches: allow small noise


def test_hlo_text_round_trips_through_parser():
    """The text we emit must be parseable back to an XlaComputation (the
    exact path the Rust runtime uses)."""
    from jax._src.lib import xla_client as xc
    model = build_model("linear2_v64")
    text, _ = lower_grad_step(model)
    # xla_client can parse HLO text back
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
