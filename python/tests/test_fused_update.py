"""L1 kernel correctness: fused_adamk_update vs the pure-jnp oracle.

Hypothesis sweeps shapes / K-modes / hyperparameters; fixed cases cover
edge shapes (1xN, Nx1, non-multiple-of-block, vectors).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_update import (fused_adamk_update, pack_scalars,
                                          v_shape_for)
from compile.kernels.ref import ref_adamk_update

K_MODES = ["none", "fan_out", "fan_in", "both"]


def _mk(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _run_case(shape, k_mode, seed=0, beta1=0.9, beta2=0.95, lr=1e-2,
              wd=0.1, step=3):
    rng = np.random.default_rng(seed)
    w = _mk(rng, shape)
    m = 0.1 * _mk(rng, shape)
    g = _mk(rng, shape)
    vs = v_shape_for(shape, k_mode) if len(shape) > 1 else \
        v_shape_for(shape, k_mode)
    v = jnp.abs(_mk(rng, vs)) * 1e-3
    s = pack_scalars(beta1, beta2, 1e-8, lr, wd, step)
    got = fused_adamk_update(w, m, v, g, s, k_mode=k_mode)
    want = ref_adamk_update(w, m, v, g, s, k_mode=k_mode)
    for a, b, name in zip(got, want, ["w", "m", "v"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6, err_msg=name)
    return got


@pytest.mark.parametrize("k_mode", K_MODES)
@pytest.mark.parametrize("shape", [(8, 16), (16, 8), (1, 32), (32, 1),
                                   (7, 13), (64, 48)])
def test_matrix_shapes(shape, k_mode):
    _run_case(shape, k_mode)


@pytest.mark.parametrize("k_mode", ["none", "both", "all"])
def test_vector_shapes(k_mode):
    _run_case((17,), k_mode)
    _run_case((1,), k_mode)


@pytest.mark.parametrize("k_mode", K_MODES)
def test_tiled_path_matches_untiled(k_mode):
    """Shapes larger than the block limit exercise multi-step grids."""
    _run_case((512, 96), k_mode)
    _run_case((96, 512), k_mode)


def test_v_shapes():
    assert v_shape_for((8, 16), "none") == (8, 16)
    assert v_shape_for((8, 16), "fan_out") == (1, 16)
    assert v_shape_for((8, 16), "fan_in") == (8, 1)
    assert v_shape_for((8, 16), "both") == (1, 1)
    assert v_shape_for((9,), "all") == (1,)
    assert v_shape_for((9,), "none") == (9,)


def test_k_none_equals_adamw():
    """K=none must reproduce exact AdamW (paper: family coincides with Adam)."""
    rng = np.random.default_rng(3)
    shape = (12, 24)
    w, g = _mk(rng, shape), _mk(rng, shape)
    m = jnp.zeros(shape)
    v = jnp.zeros(shape)
    beta1, beta2, eps, lr, wd, step = 0.9, 0.95, 1e-8, 1e-2, 0.1, 1
    s = pack_scalars(beta1, beta2, eps, lr, wd, step)
    nw, nm, nv = fused_adamk_update(w, m, v, g, s, k_mode="none")
    m_ref = (1 - beta1) * g
    v_ref = (1 - beta2) * g * g
    mh = m_ref / (1 - beta1)
    vh = v_ref / (1 - beta2)
    w_ref = w - lr * (mh / (jnp.sqrt(vh) + eps) + wd * w)
    np.testing.assert_allclose(np.asarray(nw), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-7)


def test_compressed_v_equals_mean_of_full_v():
    """E_K compression commutes with the EMA: running the K=fan_in kernel
    must equal averaging the K=none V over fan_in at every step."""
    rng = np.random.default_rng(11)
    shape = (6, 10)
    w = _mk(rng, shape)
    m = jnp.zeros(shape)
    v_full = jnp.zeros(shape)
    v_red = jnp.zeros((6, 1))
    for step in range(1, 4):
        g = _mk(rng, shape)
        s = pack_scalars(0.9, 0.95, 1e-8, 1e-2, 0.0, step)
        _, _, v_full = fused_adamk_update(w, m, v_full, g, s, k_mode="none")
        _, _, v_red = fused_adamk_update(w, m, v_red, g, s, k_mode="fan_in")
        np.testing.assert_allclose(np.asarray(jnp.mean(v_full, 1, keepdims=True)),
                                   np.asarray(v_red), rtol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 40),
    cols=st.integers(1, 40),
    k_idx=st.integers(0, 3),
    seed=st.integers(0, 2 ** 16),
    lr=st.floats(1e-5, 1e-1),
    step=st.integers(1, 200),
)
def test_hypothesis_sweep(rows, cols, k_idx, seed, lr, step):
    _run_case((rows, cols), K_MODES[k_idx], seed=seed, lr=lr, step=step)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 257), seed=st.integers(0, 999))
def test_hypothesis_vectors(n, seed):
    _run_case((n,), "both", seed=seed)
    _run_case((n,), "none", seed=seed)


def test_jit_lowering_contains_no_python():
    """The kernel must lower to pure HLO (no callbacks) for the AOT path."""
    w = jnp.ones((8, 8))
    s = pack_scalars(0.9, 0.95, 1e-8, 1e-2, 0.0, 1)
    lowered = jax.jit(
        lambda w, m, v, g, s: fused_adamk_update(w, m, v, g, s, k_mode="fan_in")
    ).lower(w, w, jnp.ones((8, 1)), w, s)
    text = lowered.compiler_ir("stablehlo")
    assert "callback" not in str(text).lower()
