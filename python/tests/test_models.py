"""L2 model sanity: shapes, loss finiteness, grads, spec/manifest integrity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_model
from compile.models import gpt, linear2, llama, resnet, vit

SMALL_MODELS = ["gpt_nano", "llama_tiny", "vit_mini_c10", "resnet_mini_c10",
                "linear2_v64"]


def _batch_for(model, rng):
    out = []
    for (name, shape, dt) in model.batch_specs:
        if dt == "s32":
            hi = model.meta.get("vocab", model.meta.get("classes", 2))
            out.append(jnp.asarray(rng.integers(0, hi, shape).astype(np.int32)))
        else:
            out.append(jnp.asarray(rng.standard_normal(shape).astype(np.float32)))
    return out


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_loss_finite_and_grads_complete(name):
    model = build_model(name)
    rng = np.random.default_rng(0)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(model, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, *batch)
    assert jnp.isfinite(loss), name
    assert len(grads) == len(model.specs)
    for spec, g in zip(model.specs, grads):
        assert g.shape == spec.shape, spec.name
        assert bool(jnp.all(jnp.isfinite(g))), spec.name


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_initial_loss_near_uniform(name):
    """At init, LM/classifier loss should be ~ log(n_classes)."""
    model = build_model(name)
    rng = np.random.default_rng(1)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(model, rng)
    loss = float(model.loss(params, *batch))
    n = model.meta.get("vocab") or model.meta.get("classes")
    expect = np.log(n)
    assert abs(loss - expect) < 0.35 * expect + 1.0, (loss, expect)


def test_gpt_param_count_nano():
    model = build_model("gpt_nano")
    n = sum(int(np.prod(s.shape)) for s in model.specs)
    # 2 embeddings + 4 blocks of (2 LN + 4 attn d^2 + 8d^2 MLP) + final LN
    cfg = gpt.PRESETS["gpt_nano"]
    d = cfg.d_model
    expect = (cfg.vocab * d + cfg.ctx * d
              + cfg.n_layers * (2 * d + 4 * d * d + 2 * 4 * d * d) + d)
    assert n == expect


def test_gpt_weight_tying_gradient_flows_to_embedding():
    """With tying, the LM head gradient lands on tok_embd."""
    model = build_model("gpt_nano")
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    batch = _batch_for(model, rng)
    grads = jax.grad(model.loss)(params, *batch)
    g_tok = grads[model.index("tok_embd")]
    assert float(jnp.abs(g_tok).max()) > 0


def test_specs_have_unique_names_and_both_inits():
    for name in SMALL_MODELS + ["gpt_mini", "vit_mini_c100", "resnet_mini_c100"]:
        model = build_model(name)
        names = [s.name for s in model.specs]
        assert len(names) == len(set(names)), name
        for s in model.specs:
            assert s.init_mitchell["scheme"] in (
                "normal", "uniform", "zeros", "ones", "trunc_normal")
            assert s.init_default["scheme"] in (
                "normal", "uniform", "zeros", "ones", "trunc_normal")


def test_mitchell_residual_scaling():
    """Attn.Proj / MLP.Down get the 1/sqrt(2L) std scaling (§4.3)."""
    model = build_model("gpt_nano")
    cfg = gpt.PRESETS["gpt_nano"]
    for s in model.specs:
        if s.layer_type in ("attn_proj", "mlp_down"):
            assert abs(s.init_mitchell["std"]
                       - 0.02 / (2 * cfg.n_layers) ** 0.5) < 1e-9
        elif s.layer_type in ("attn_q", "attn_k", "attn_v", "mlp_up"):
            assert s.init_mitchell["std"] == 0.02


def test_conv_specs_mark_fan_out_axis():
    model = build_model("resnet_mini_c10")
    for s in model.specs:
        if s.layer_type == "conv":
            assert s.fan_out_axis == 3
            assert len(s.shape) == 4


def test_vocab_presets_cover_sweep():
    assert set(linear2.VOCABS) == {64, 128, 256, 512, 1024, 2048, 4096}
    for v in linear2.VOCABS:
        m = build_model(f"linear2_v{v}")
        assert m.specs[0].shape == (v, 128)


def test_deterministic_init():
    model = build_model("linear2_v64")
    p1 = model.init_params(jax.random.PRNGKey(9))
    p2 = model.init_params(jax.random.PRNGKey(9))
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
