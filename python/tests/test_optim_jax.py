"""Fused-engine optimizer (optim_jax) correctness and ruleset semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_model
from compile.optim_jax import (Hypers, adamk_apply, global_norm_clip,
                               k_modes_for, make_train_step, v_shapes_for)


def _tiny():
    return build_model("linear2_v64")


def test_k_modes_adam_all_none():
    model = _tiny()
    assert k_modes_for(model, "adam") == ["none"] * len(model.specs)


def test_k_modes_slimadam_table3():
    model = build_model("gpt_nano")
    modes = dict(zip([s.name for s in model.specs],
                     k_modes_for(model, "slimadam")))
    assert modes["tok_embd"] == "fan_in"        # keep the token dimension
    assert modes["h0.attn_q"] == "fan_in"
    assert modes["h0.attn_k"] == "fan_in"
    assert modes["h0.attn_v"] == "fan_out"
    assert modes["h0.attn_proj"] == "fan_out"
    assert modes["h0.mlp_up"] == "fan_out"
    assert modes["h0.mlp_down"] == "fan_out"
    assert modes["h0.ln_attn"] == "none"        # vectors uncompressed
    assert modes["ln_final"] == "none"


def test_k_modes_adalayer_variants():
    model = build_model("gpt_nano")
    base = dict(zip([s.name for s in model.specs],
                    k_modes_for(model, "adalayer")))
    ln_tl = dict(zip([s.name for s in model.specs],
                     k_modes_for(model, "adalayer_ln_tl")))
    assert base["h0.attn_q"] == "both"
    assert base["h0.ln_attn"] == "all"
    assert ln_tl["h0.ln_attn"] == "none"
    assert ln_tl["tok_embd"] == "none"
    assert ln_tl["h0.attn_q"] == "both"


def test_v_shapes_memory_savings():
    """SlimAdam's stored V must be dramatically smaller than Adam's."""
    model = build_model("gpt_nano")
    adam_v = sum(int(np.prod(s)) for s in v_shapes_for(
        model, k_modes_for(model, "adam")))
    slim_v = sum(int(np.prod(s)) for s in v_shapes_for(
        model, k_modes_for(model, "slimadam")))
    assert slim_v < 0.12 * adam_v  # nano model: >88% savings


def test_global_norm_clip():
    g = [jnp.full((4,), 3.0), jnp.full((4,), 4.0)]  # norm = 10
    clipped, gn = global_norm_clip(g, 1.0)
    assert abs(float(gn) - 10.0) < 1e-5
    total = jnp.sqrt(sum(jnp.sum(c * c) for c in clipped))
    assert abs(float(total) - 1.0) < 1e-5
    # below threshold: untouched
    same, _ = global_norm_clip([jnp.full((2,), 0.1)], 1.0)
    np.testing.assert_allclose(np.asarray(same[0]), 0.1, rtol=1e-6)


def test_adamk_apply_matches_manual_adamw():
    """ruleset=adam through the kernel path == hand-rolled AdamW."""
    model = _tiny()
    hypers = Hypers(weight_decay=0.1)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.standard_normal(s.shape).astype(np.float32))
             for s in model.specs]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    k_modes = k_modes_for(model, "adam")
    lr = jnp.float32(1e-2)
    new_p, new_m, new_v = adamk_apply(model, k_modes, hypers, params, m, v,
                                      grads, jnp.float32(1.0), lr)
    for spec, w, g, nw in zip(model.specs, params, grads, new_p):
        mi = (1 - hypers.beta1) * g
        vi = (1 - hypers.beta2) * g * g
        mh = mi / (1 - hypers.beta1)
        vh = vi / (1 - hypers.beta2)
        wd = hypers.weight_decay if spec.wd else 0.0
        w_ref = w - lr * (mh / (jnp.sqrt(vh) + hypers.eps) + wd * w)
        np.testing.assert_allclose(np.asarray(nw), np.asarray(w_ref),
                                   rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("ruleset", ["adam", "slimadam", "adalayer"])
def test_train_step_decreases_loss(ruleset):
    model = _tiny()
    hypers = Hypers(beta1=0.9, beta2=0.95, weight_decay=0.0, clip_norm=1.0)
    step_fn, k_modes = make_train_step(model, ruleset, hypers)
    step_fn = jax.jit(step_fn)
    params = model.init_params(jax.random.PRNGKey(1))
    v_shapes = v_shapes_for(model, k_modes)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros(s, jnp.float32) for s in v_shapes]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 64, (16, 32)).astype(np.int32))
    y = jnp.asarray(rng.integers(0, 64, (16, 32)).astype(np.int32))
    n = len(model.specs)
    first = None
    for t in range(1, 21):
        out = step_fn(*params, *m, *v, x, y, jnp.float32(t), jnp.float32(3e-3))
        loss = float(out[0])
        params = list(out[2:2 + n])
        m = list(out[2 + n:2 + 2 * n])
        v = list(out[2 + 2 * n:2 + 3 * n])
        if first is None:
            first = loss
    assert loss < first - 0.1, (ruleset, first, loss)


def test_conv_tensor_roundtrip_via_matrix_view():
    """adamk_apply on a 4-D conv weight must equal updating its matrix view."""
    model = build_model("resnet_mini_c10")
    hypers = Hypers(weight_decay=0.0)
    idx = model.index("stem.conv")
    spec = model.specs[idx]
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal(spec.shape).astype(np.float32))
    g = jnp.asarray(rng.standard_normal(spec.shape).astype(np.float32))
    params = [w]
    grads = [g]
    m = [jnp.zeros_like(w)]
    v = [jnp.zeros((1, 1), jnp.float32)]

    class FakeModel:
        specs = [spec]

    new_p, _, new_v = adamk_apply(FakeModel, ["both"], hypers, params, m, v,
                                  grads, jnp.float32(1.0), jnp.float32(1e-2))
    assert new_p[0].shape == spec.shape
    # v is the mean of g^2 scaled by (1-beta2)
    expect_v = (1 - hypers.beta2) * float(jnp.mean(g * g))
    np.testing.assert_allclose(float(new_v[0][0, 0]), expect_v, rtol=1e-5)
