"""L1 SNR kernel vs the pure-jnp oracle (Eq. 3)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_snr_stats
from compile.kernels.snr import snr_stats


def _check(v):
    got = np.asarray(snr_stats(jnp.asarray(v)))
    want = np.asarray(ref_snr_stats(jnp.asarray(v)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-6)


@pytest.mark.parametrize("shape", [(4, 4), (1, 16), (16, 1), (7, 13),
                                   (64, 48), (300, 40)])
def test_matrix_shapes(shape):
    rng = np.random.default_rng(0)
    _check(np.abs(rng.standard_normal(shape)).astype(np.float32) + 1e-4)


def test_vector():
    rng = np.random.default_rng(1)
    _check(np.abs(rng.standard_normal(33)).astype(np.float32))


def test_constant_matrix_has_huge_snr():
    """A constant V is perfectly compressible -> SNR far above any cutoff."""
    v = np.full((8, 8), 0.25, np.float32)
    got = np.asarray(snr_stats(jnp.asarray(v)))
    assert (got > 1e6).all()


def test_high_variance_low_snr():
    """One dominant outlier per column crushes the fan_out SNR."""
    rng = np.random.default_rng(2)
    v = np.abs(rng.standard_normal((64, 16))).astype(np.float32) * 1e-3
    v[0, :] = 100.0  # heavy tail along axis 0
    got = np.asarray(snr_stats(jnp.asarray(v)))
    assert got[0] < 1.0  # fan_out (axis-0 groups) incompressible


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 50), cols=st.integers(1, 50),
       seed=st.integers(0, 2 ** 16), scale=st.floats(1e-6, 1e3))
def test_hypothesis_sweep(rows, cols, seed, scale):
    rng = np.random.default_rng(seed)
    v = (np.abs(rng.standard_normal((rows, cols))) * scale + 1e-8)
    _check(v.astype(np.float32))


def test_row_tiled_streaming_matches():
    """Row counts above the 256-row block exercise the streaming grid."""
    rng = np.random.default_rng(5)
    _check(np.abs(rng.standard_normal((1024, 32))).astype(np.float32) + 1e-5)
