"""Build-time Python package: Pallas kernels (L1), JAX models (L2) and the
AOT lowering pipeline that produces the HLO-text artifacts executed by the
Rust runtime. Nothing in this package is imported at run time.
"""
