"""Fused generalized-Adam (Eq. 2 of the paper) update as a Pallas kernel.

The paper's low-memory Adam family replaces the per-parameter second moment
with its mean over a set of sharing dimensions K:

    V_{t+1} = beta2 * V_t + (1 - beta2) * E_K[G_t^2]          (Eq. 2)

with K in {none, fan_out (axis 0), fan_in (axis 1), both}. The second moment
is *stored at the reduced shape* — that is where the memory saving comes
from — and broadcast back inside the update:

    M_{t+1} = beta1 * M_t + (1 - beta1) * G_t
    W_{t+1} = W_t - lr * ( Mhat / (sqrt(Vhat) + eps) + wd * W_t )

with bias corrections Mhat = M/(1-beta1^t), Vhat = V/(1-beta2^t) and
decoupled (AdamW-style) weight decay.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel is elementwise
plus a row/column reduction, i.e. VPU-bound. We tile the weight block
through VMEM along the axis *orthogonal* to the reduction axis so each
grid step owns complete reduction groups and the compressed V tile stays
resident in VMEM:

  * K = fan_in  (mean over axis 1) -> grid over fan_out row-blocks,
    block = (BR, fan_in), V tile = (BR, 1)
  * K = fan_out (mean over axis 0) -> grid over fan_in column-blocks,
    block = (fan_out, BC), V tile = (1, BC)
  * K = none / both -> grid over rows; `both` performs a two-pass reduction
    (per-row partial means accumulated into a scalar) only when the whole
    matrix does not fit one block; for the model sizes lowered in this
    repo a single block always suffices and we assert so.

Scalars (beta1, beta2, eps, lr, wd, bias corrections) are passed as a
(1, 8) f32 operand broadcast to every grid step (index_map -> (0, 0)),
which interpret-mode Pallas places alongside the tile (on real TPU this
would be an SMEM scalar-prefetch operand).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sharing-dimension modes, in the paper's K notation. "fan_out" averages
# over axis 0 (the fan_out axis of a (fan_out, fan_in) weight), "fan_in"
# over axis 1. "all" (used by AdaLayer / for 1-D tensors) averages over
# every axis and is represented here by "both" for 2-D operands.
K_MODES = ("none", "fan_out", "fan_in", "both")

# Default row/column tile extents. 256x256 f32 tiles keep the working set
# (w, m, g, out_w, out_m tiles) ~1.25 MiB, far under the ~16 MiB VMEM
# budget, leaving room for double buffering on a real TPU.
_BLOCK_ROWS = 256
_BLOCK_COLS = 256

_N_SCALARS = 8  # beta1, beta2, eps, lr, wd, bc1, bc2, unused


def v_shape_for(shape: tuple[int, ...], k_mode: str) -> tuple[int, ...]:
    """Stored (reduced) shape of the second moment for a given K mode."""
    if len(shape) == 1:
        if k_mode in ("none",):
            return shape
        if k_mode in ("both", "all", "fan_out", "fan_in"):
            return (1,)
        raise ValueError(f"bad k_mode {k_mode!r} for 1-D tensor")
    if len(shape) != 2:
        raise ValueError("fused_adamk_update handles 1-D and 2-D tensors; "
                         f"got shape {shape}")
    r, c = shape
    if k_mode == "none":
        return (r, c)
    if k_mode == "fan_out":
        return (1, c)
    if k_mode == "fan_in":
        return (r, 1)
    if k_mode in ("both", "all"):
        return (1, 1)
    raise ValueError(f"unknown k_mode {k_mode!r}")


def _update_math(k_mode, s, w, m, v, g):
    """Shared update arithmetic used by every kernel body.

    ``v`` has the reduced shape for ``k_mode``; returns (w', m', v').
    """
    beta1, beta2, eps, lr, wd, bc1, bc2 = (
        s[0, 0], s[0, 1], s[0, 2], s[0, 3], s[0, 4], s[0, 5], s[0, 6])
    g2 = g * g
    if k_mode == "none":
        ek = g2
    elif k_mode == "fan_out":
        ek = jnp.mean(g2, axis=0, keepdims=True)
    elif k_mode == "fan_in":
        ek = jnp.mean(g2, axis=1, keepdims=True)
    else:  # both
        ek = jnp.mean(g2, keepdims=True)
    v_new = beta2 * v + (1.0 - beta2) * ek
    m_new = beta1 * m + (1.0 - beta1) * g
    m_hat = m_new * bc1
    v_hat = v_new * bc2
    w_new = w - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * w)
    return w_new, m_new, v_new


def _make_kernel(k_mode):
    def kernel(s_ref, w_ref, m_ref, v_ref, g_ref, ow_ref, om_ref, ov_ref):
        w_new, m_new, v_new = _update_math(
            k_mode, s_ref[...], w_ref[...], m_ref[...], v_ref[...], g_ref[...])
        ow_ref[...] = w_new
        om_ref[...] = m_new
        ov_ref[...] = v_new
    return kernel


def _pick_block(extent: int, limit: int) -> int:
    """Largest divisor of ``extent`` that is <= limit (keeps tiling exact)."""
    if extent <= limit:
        return extent
    for cand in range(limit, 0, -1):
        if extent % cand == 0:
            return cand
    return extent


@functools.partial(jax.jit, static_argnames=("k_mode",))
def fused_adamk_update(w, m, v, g, scalars, *, k_mode: str = "none"):
    """Apply one fused generalized-Adam step to a single weight tensor.

    Args:
      w, m, g: (fan_out, fan_in) or (n,) f32 tensors.
      v: second moment at the reduced shape ``v_shape_for(w.shape, k_mode)``.
      scalars: (1, 8) f32 — [beta1, beta2, eps, lr, wd, bc1, bc2, 0] where
        bc1 = 1/(1-beta1^t), bc2 = 1/(1-beta2^t) (bias-correction factors
        computed by the caller so the kernel stays step-free).
      k_mode: sharing dimensions K per the paper's notation.

    Returns:
      (w', m', v') with v' at the reduced shape.
    """
    squeeze = False
    if w.ndim == 1:
        # Promote vectors to a 1-row matrix; "all"/"both" then shares one
        # moment across the vector, matching the paper's vector handling.
        k_mode2 = {"none": "none"}.get(k_mode, "both")
        w, m, g = w[None, :], m[None, :], g[None, :]
        v = v[None, :] if v.ndim == 1 else v
        k_mode = k_mode2
        squeeze = True

    r, c = w.shape
    vs = v_shape_for((r, c), k_mode)
    assert v.shape == vs, f"v shape {v.shape} != expected {vs} for K={k_mode}"

    kernel = _make_kernel(k_mode)
    out_shape = [
        jax.ShapeDtypeStruct((r, c), w.dtype),
        jax.ShapeDtypeStruct((r, c), w.dtype),
        jax.ShapeDtypeStruct(vs, w.dtype),
    ]

    if k_mode == "fan_in":
        # Tile rows; each tile owns full reduction rows.
        br = _pick_block(r, _BLOCK_ROWS)
        grid = (r // br,)
        full = pl.BlockSpec((br, c), lambda i: (i, 0))
        vred = pl.BlockSpec((br, 1), lambda i: (i, 0))
        sspec = pl.BlockSpec((1, _N_SCALARS), lambda i: (0, 0))
        in_specs = [sspec, full, full, vred, full]
        out_specs = [full, full, vred]
    elif k_mode == "fan_out":
        # Tile columns; each tile owns full reduction columns.
        bc_ = _pick_block(c, _BLOCK_COLS)
        grid = (c // bc_,)
        full = pl.BlockSpec((r, bc_), lambda j: (0, j))
        vred = pl.BlockSpec((1, bc_), lambda j: (0, j))
        sspec = pl.BlockSpec((1, _N_SCALARS), lambda j: (0, 0))
        in_specs = [sspec, full, full, vred, full]
        out_specs = [full, full, vred]
    elif k_mode == "none":
        br = _pick_block(r, _BLOCK_ROWS)
        grid = (r // br,)
        full = pl.BlockSpec((br, c), lambda i: (i, 0))
        sspec = pl.BlockSpec((1, _N_SCALARS), lambda i: (0, 0))
        in_specs = [sspec, full, full, full, full]
        out_specs = [full, full, full]
    else:  # both — single block (asserted small enough for one VMEM tile)
        grid = (1,)
        full = pl.BlockSpec((r, c), lambda i: (0, 0))
        vred = pl.BlockSpec((1, 1), lambda i: (0, 0))
        sspec = pl.BlockSpec((1, _N_SCALARS), lambda i: (0, 0))
        in_specs = [sspec, full, full, vred, full]
        out_specs = [full, full, vred]

    ow, om, ov = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT target; see module docstring
    )(scalars, w, m, v, g)

    if squeeze:
        ow, om = ow[0], om[0]
        ov = ov[0]
    return ow, om, ov


def pack_scalars(beta1, beta2, eps, lr, wd, step):
    """Build the (1, 8) scalar operand; ``step`` is 1-based."""
    bc1 = 1.0 / (1.0 - beta1 ** step)
    bc2 = 1.0 / (1.0 - beta2 ** step)
    return jnp.array([[beta1, beta2, eps, lr, wd, bc1, bc2, 0.0]],
                     dtype=jnp.float32)
