"""SNR_K statistics (Eq. 3 of the paper) as a Pallas kernel.

    SNR_K(V) = E_{K'}[ (E_K[V])^2 / Var_K[V] ]

where E_K / Var_K reduce over the sharing dimensions K and the outer mean
runs over the remaining dimensions K'. The kernel computes, for one 2-D
second-moment tensor, the three paper K-modes in a single pass:

    out[0] = SNR_{fan_out}(V)   (reduce axis 0)
    out[1] = SNR_{fan_in}(V)    (reduce axis 1)
    out[2] = SNR_{both}(V)      (reduce both axes)

Variance uses the population convention (matching ``jnp.var`` /
``np.var`` with ddof=0), and a tiny floor avoids 0/0 for constant slices
(a constant slice is perfectly compressible; the floor maps it to a very
large, finite SNR).

The kernel tiles rows through VMEM and accumulates per-column partial sums
(sum and sum-of-squares) in the output accumulators, finishing the ratio
on the last grid step — the standard two-moment streaming reduction, which
on a real TPU keeps each pass HBM-minimal (V is read exactly once).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VAR_FLOOR = 1e-30


def _snr_from_moments(s1, s2, n):
    """SNR of groups given group sums s1, sum-of-squares s2, group size n."""
    mean = s1 / n
    var = s2 / n - mean * mean
    var = jnp.maximum(var, VAR_FLOOR)
    return (mean * mean) / var


def _kernel(v_ref, out_ref, acc_ref):
    """Row-tiled streaming kernel.

    acc_ref: (3, C) f32 scratch-like accumulator laid out as an output:
      row 0 — per-column running sum of V
      row 1 — per-column running sum of V^2
      row 2 — unused padding (keeps the accumulator 2-D and lane-aligned)
    out_ref: (1, 4) f32 — [snr_fan_out, snr_fan_in, snr_both, 0].
    """
    i = pl.program_id(0)
    nrows_total = pl.num_programs(0) * v_ref.shape[0]
    v = v_ref[...]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    # Streaming per-column moments for the axis-0 (fan_out) reduction and
    # the full-matrix reduction.
    acc_ref[0, :] = acc_ref[0, :] + jnp.sum(v, axis=0)
    acc_ref[1, :] = acc_ref[1, :] + jnp.sum(v * v, axis=0)

    # fan_in (axis-1) groups are complete within each row tile: accumulate
    # the *sum of per-row SNRs* directly into the output.
    c = v.shape[1]
    row_s1 = jnp.sum(v, axis=1)
    row_s2 = jnp.sum(v * v, axis=1)
    snr_rows = _snr_from_moments(row_s1, row_s2, jnp.float32(c))
    out_ref[0, 1] = out_ref[0, 1] + jnp.sum(snr_rows)

    @pl.when(i == pl.num_programs(0) - 1)
    def _finish():
        n_r = jnp.float32(nrows_total)
        col_snr = _snr_from_moments(acc_ref[0, :], acc_ref[1, :], n_r)
        out_ref[0, 0] = jnp.mean(col_snr)                    # E_{K'} over cols
        out_ref[0, 1] = out_ref[0, 1] / n_r                  # E_{K'} over rows
        tot_s1 = jnp.sum(acc_ref[0, :])
        tot_s2 = jnp.sum(acc_ref[1, :])
        out_ref[0, 2] = _snr_from_moments(
            tot_s1, tot_s2, n_r * jnp.float32(v.shape[1]))   # scalar group
        out_ref[0, 3] = 0.0


def _pick_block(extent: int, limit: int) -> int:
    if extent <= limit:
        return extent
    for cand in range(limit, 0, -1):
        if extent % cand == 0:
            return cand
    return extent


@jax.jit
def snr_stats(v):
    """Compute [SNR_fan_out, SNR_fan_in, SNR_both] for a 2-D tensor ``v``.

    Returns a (3,) f32 vector. For 1-D tensors, returns
    [SNR_all, SNR_all, SNR_all] where SNR_all treats the vector as one
    group (mean^2/var over the whole vector).
    """
    if v.ndim == 1:
        v = v[None, :]
        r, c = v.shape
        s1 = jnp.sum(v)
        s2 = jnp.sum(v * v)
        snr = _snr_from_moments(s1, s2, jnp.float32(r * c))
        return jnp.stack([snr, snr, snr])

    r, c = v.shape
    br = _pick_block(r, 256)
    grid = (r // br,)
    out, _acc = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0)),
                   pl.BlockSpec((3, c), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 4), jnp.float32),
                   jax.ShapeDtypeStruct((3, c), jnp.float32)],
        interpret=True,
    )(v.astype(jnp.float32))
    return out[0, :3]
