"""Layer-1 Pallas kernels for the SlimAdam reproduction.

All kernels are authored as TPU Pallas kernels but lowered with
``interpret=True`` so they execute on the CPU PJRT backend (real-TPU
lowering emits Mosaic custom-calls the CPU plugin cannot run). Numerical
correctness is validated against the pure-jnp oracles in ``ref.py`` by the
pytest suite (hypothesis sweeps over shapes / K-modes).
"""

from .fused_update import fused_adamk_update, v_shape_for  # noqa: F401
from .snr import snr_stats  # noqa: F401
