"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

These implementations are deliberately written in the most direct way
possible (no tiling, no streaming accumulation) so that any disagreement
with the kernels points at the kernel, not the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from .fused_update import v_shape_for

VAR_FLOOR = 1e-30


def ref_adamk_update(w, m, v, g, scalars, *, k_mode: str = "none"):
    """Reference generalized-Adam update (Eq. 2 + AdamW step)."""
    s = scalars.reshape(-1)
    beta1, beta2, eps, lr, wd, bc1, bc2 = [s[i] for i in range(7)]

    squeeze = False
    if w.ndim == 1:
        k_mode = "none" if k_mode == "none" else "both"
        w, m, g = w[None, :], m[None, :], g[None, :]
        v = v[None, :] if v.ndim == 1 else v
        squeeze = True

    g2 = g * g
    if k_mode == "none":
        ek = g2
    elif k_mode == "fan_out":
        ek = jnp.mean(g2, axis=0, keepdims=True)
    elif k_mode == "fan_in":
        ek = jnp.mean(g2, axis=1, keepdims=True)
    else:
        ek = jnp.mean(g2, keepdims=True)

    v_new = beta2 * v + (1.0 - beta2) * ek
    m_new = beta1 * m + (1.0 - beta1) * g
    w_new = w - lr * ((m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps) + wd * w)

    if squeeze:
        return w_new[0], m_new[0], v_new[0]
    return w_new, m_new, v_new


def ref_snr(v, k_mode: str):
    """Reference SNR_K (Eq. 3): E_{K'}[ mean_K(V)^2 / var_K(V) ]."""
    v = jnp.asarray(v, jnp.float32)
    if v.ndim == 1:
        mean = jnp.mean(v)
        var = jnp.maximum(jnp.var(v), VAR_FLOOR)
        return (mean * mean) / var
    if k_mode == "fan_out":
        axis = 0
    elif k_mode == "fan_in":
        axis = 1
    elif k_mode in ("both", "all"):
        mean = jnp.mean(v)
        var = jnp.maximum(jnp.var(v), VAR_FLOOR)
        return (mean * mean) / var
    else:
        raise ValueError(f"no SNR for k_mode {k_mode!r}")
    mean = jnp.mean(v, axis=axis)
    var = jnp.maximum(jnp.var(v, axis=axis), VAR_FLOOR)
    return jnp.mean((mean * mean) / var)


def ref_snr_stats(v):
    """Reference for kernels.snr.snr_stats: (3,) vector of SNRs."""
    if v.ndim == 1:
        s = ref_snr(v, "both")
        return jnp.stack([s, s, s])
    return jnp.stack([ref_snr(v, "fan_out"),
                      ref_snr(v, "fan_in"),
                      ref_snr(v, "both")])


__all__ = ["ref_adamk_update", "ref_snr", "ref_snr_stats", "v_shape_for"]
