"""Fused-engine optimizer: the generalized low-memory Adam family (Eq. 2)
expressed in JAX over a model's flat parameter list, calling the Layer-1
Pallas kernel per tensor. ``make_train_step`` composes model fwd/bwd with
this update into the single-dispatch ``train_step`` HLO the Rust runtime
executes on its hot path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.fused_update import fused_adamk_update, v_shape_for
from .models.common import Model


@dataclasses.dataclass
class Hypers:
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


# SlimAdam's recommended rules (paper Table 3) in this repo's storage
# convention: weights are (fan_out, fan_in); embeddings/LM heads are
# (vocab, d) so "fan_in" (axis 1 = embedding axis) preserves the
# incompressible token dimension. Vector-likes stay uncompressed.
TABLE3_RULES = {
    "attn_q": "fan_in",
    "attn_k": "fan_in",
    "attn_v": "fan_out",
    "attn_proj": "fan_out",
    "mlp_up": "fan_out",
    "mlp_gate": "fan_out",
    "mlp_down": "fan_out",
    "tok_embd": "fan_in",
    "lm_head": "fan_in",
    "patch_embd": "fan_in",
    "head": "fan_in",
    # conv matrix view is (C_out, C_in*kh*kw): average fan_in — one
    # second moment per output filter (mirrors rules::RuleSet::
    # table3_default on the Rust side; the two must agree or fused
    # artifacts and the split path train with different states)
    "conv": "fan_in",
    "pos_embd": "none",
    "cls_token": "none",
    "ln_attn": "none",
    "ln_mlp": "none",
    "ln_final": "none",
    "bn": "none",
}


def k_modes_for(model: Model, ruleset: str) -> list:
    """Per-tensor K modes for a named ruleset."""
    modes = []
    for spec in model.specs:
        if ruleset == "adam":
            modes.append("none")
        elif ruleset == "adalayer":
            modes.append("both" if len(spec.shape) > 1 else "all")
        elif ruleset == "adalayer_ln_tl":
            if spec.layer_type in ("ln_attn", "ln_mlp", "ln_final", "bn",
                                   "tok_embd", "lm_head"):
                modes.append("none")
            else:
                modes.append("both" if len(spec.shape) > 1 else "all")
        elif ruleset == "slimadam":
            if len(spec.shape) == 1:
                modes.append("none")  # vectors stay uncompressed
            else:
                modes.append(TABLE3_RULES.get(spec.layer_type, "none"))
        else:
            raise ValueError(f"unknown ruleset {ruleset!r}")
    return modes


def v_shapes_for(model: Model, k_modes) -> list:
    shapes = []
    for spec, k in zip(model.specs, k_modes):
        shape = spec.shape
        if len(shape) > 2:
            # Conv tensors are updated in their matrix view.
            fo = shape[spec.fan_out_axis]
            fi = int(jnp.prod(jnp.array(shape)) // fo)
            shape = (fo, fi)
        shapes.append(v_shape_for(shape, _norm_k(k, len(spec.shape))))
    return shapes


def _norm_k(k, ndim):
    if ndim == 1:
        return "none" if k == "none" else "both"
    return "both" if k == "all" else k


def global_norm_clip(grads, clip):
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads))
    scale = jnp.minimum(1.0, clip / (gn + 1e-12))
    return [g * scale for g in grads], gn


def adamk_apply(model: Model, k_modes, hypers: Hypers,
                params, m, v, grads, step, lr):
    """One generalized-Adam update across the parameter list.

    ``step`` is a 1-based f32 scalar; bias corrections are computed here so
    the kernel stays step-free. Conv tensors round-trip through their
    (fan_out, fan_in) matrix view.
    """
    bc1 = 1.0 / (1.0 - jnp.power(hypers.beta1, step))
    bc2 = 1.0 / (1.0 - jnp.power(hypers.beta2, step))
    new_p, new_m, new_v = [], [], []
    for spec, k, w, mi, vi, g in zip(model.specs, k_modes, params, m, v, grads):
        wd = hypers.weight_decay if spec.wd else 0.0
        scalars = jnp.stack([jnp.float32(hypers.beta1), jnp.float32(hypers.beta2),
                             jnp.float32(hypers.eps), lr, jnp.float32(wd),
                             bc1, bc2, jnp.float32(0.0)])[None, :]
        km = _norm_k(k, len(spec.shape))
        orig_shape = w.shape
        if w.ndim > 2:
            fo_ax = spec.fan_out_axis
            perm = (fo_ax,) + tuple(i for i in range(w.ndim) if i != fo_ax)
            inv = tuple(perm.index(i) for i in range(w.ndim))
            mat = lambda t: t.transpose(perm).reshape(t.shape[fo_ax], -1)
            w2, m2, g2 = mat(w), mat(mi), mat(g)
            nw, nm, nv = fused_adamk_update(w2, m2, vi, g2, scalars, k_mode=km)
            tshape = tuple(orig_shape[i] for i in perm)
            nw = nw.reshape(tshape).transpose(inv)
            nm = nm.reshape(tshape).transpose(inv)
        else:
            nw, nm, nv = fused_adamk_update(w, mi, vi, g, scalars, k_mode=km)
        new_p.append(nw)
        new_m.append(nm)
        new_v.append(nv)
    return new_p, new_m, new_v


def make_train_step(model: Model, ruleset: str, hypers: Hypers):
    """Build the fused train_step callable (flat positional signature).

    Signature: f(*params, *m, *v, batch..., step, lr)
             -> (loss, grad_norm, *params', *m', *v')
    """
    n = len(model.specs)
    k_modes = k_modes_for(model, ruleset)

    def train_step(*args):
        params = list(args[:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        nb = len(model.batch_specs)
        batch = args[3 * n:3 * n + nb]
        step, lr = args[3 * n + nb], args[3 * n + nb + 1]
        loss, grads = jax.value_and_grad(model.loss)(params, *batch)
        grads, gnorm = global_norm_clip(grads, hypers.clip_norm)
        new_p, new_m, new_v = adamk_apply(
            model, k_modes, hypers, params, m, v, grads, step, lr)
        return (loss, gnorm, *new_p, *new_m, *new_v)

    return train_step, k_modes


def make_grad_step(model: Model):
    """Split-engine artifact: f(*params, batch...) -> (loss, *grads)."""
    n = len(model.specs)

    def grad_step(*args):
        params = list(args[:n])
        batch = args[n:]
        loss, grads = jax.value_and_grad(model.loss)(params, *batch)
        return (loss, *grads)

    return grad_step
