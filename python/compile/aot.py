"""AOT lowering pipeline: JAX → HLO text + JSON manifest (+ fixtures).

Run once via ``make artifacts``; Python never executes on the request path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --outdir ../artifacts [--only NAME] [--large]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import optim_jax
from .models import gpt, linear2, llama, native_mlp, resnet, vit
from .models.common import Model
from .optim_jax import Hypers, make_grad_step, make_train_step

_DTYPES = {"f32": jnp.float32, "s32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def build_model(name: str) -> Model:
    for mod in (gpt, llama, vit, resnet, linear2, native_mlp):
        if name in mod.PRESETS:
            return mod.build(mod.PRESETS[name])
    raise KeyError(f"no model preset named {name!r}")


def _example_args(model: Model):
    params = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.specs]
    batch = [jax.ShapeDtypeStruct(shape, _DTYPES[dt])
             for (_n, shape, dt) in model.batch_specs]
    return params, batch


def lower_grad_step(model: Model) -> tuple[str, dict]:
    params, batch = _example_args(model)
    fn = make_grad_step(model)
    lowered = jax.jit(fn).lower(*params, *batch)
    text = to_hlo_text(lowered)
    manifest = {
        "kind": "grad_step",
        "model": model.meta,
        "params": [s.to_json() for s in model.specs],
        "batch": [{"name": n, "shape": list(sh), "dtype": dt}
                  for (n, sh, dt) in model.batch_specs],
        "inputs": ([f"param:{s.name}" for s in model.specs]
                   + [f"batch:{n}" for (n, _s, _d) in model.batch_specs]),
        "outputs": (["loss"] + [f"grad:{s.name}" for s in model.specs]),
    }
    return text, manifest


def lower_train_step(model: Model, ruleset: str, hypers: Hypers) -> tuple[str, dict]:
    params, batch = _example_args(model)
    fn, k_modes = make_train_step(model, ruleset, hypers)
    v_shapes = optim_jax.v_shapes_for(model, k_modes)
    m = params
    v = [jax.ShapeDtypeStruct(vs, jnp.float32) for vs in v_shapes]
    scal = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(*params, *m, *v, *batch, scal, scal)
    text = to_hlo_text(lowered)
    manifest = {
        "kind": "train_step",
        "ruleset": ruleset,
        "model": model.meta,
        "hypers": {"beta1": hypers.beta1, "beta2": hypers.beta2,
                   "eps": hypers.eps, "weight_decay": hypers.weight_decay,
                   "clip_norm": hypers.clip_norm},
        "params": [s.to_json() for s in model.specs],
        "k_modes": k_modes,
        "v_shapes": [list(vs) for vs in v_shapes],
        "batch": [{"name": n, "shape": list(sh), "dtype": dt}
                  for (n, sh, dt) in model.batch_specs],
        "inputs": ([f"param:{s.name}" for s in model.specs]
                   + [f"m:{s.name}" for s in model.specs]
                   + [f"v:{s.name}" for s in model.specs]
                   + [f"batch:{n}" for (n, _s, _d) in model.batch_specs]
                   + ["scalar:step", "scalar:lr"]),
        "outputs": (["loss", "grad_norm"]
                    + [f"param:{s.name}" for s in model.specs]
                    + [f"m:{s.name}" for s in model.specs]
                    + [f"v:{s.name}" for s in model.specs]),
    }
    return text, manifest


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------

GRAD_MODELS = (
    "gpt_nano", "gpt_nano_w192", "gpt_mini", "llama_tiny",
    "vit_mini_c10", "vit_mini_c100", "resnet_mini_c10", "resnet_mini_c100",
) + tuple(f"linear2_v{v}" for v in linear2.VOCABS)

# Fused single-dispatch engines: (model, ruleset, beta2)
FUSED = (
    ("gpt_nano", "adam"),
    ("gpt_nano", "slimadam"),
    ("gpt_nano", "adalayer"),
    ("gpt_mini", "adam"),
    ("gpt_mini", "slimadam"),
)

LARGE_GRAD_MODELS = ("gpt_small",)

LM_HYPERS = Hypers(beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
                   clip_norm=1.0)


def write_artifact(outdir, name, text, manifest):
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    man_path = os.path.join(outdir, f"{name}.manifest.json")
    with open(hlo_path, "w") as f:
        f.write(text)
    manifest["hlo_sha256"] = hashlib.sha256(text.encode()).hexdigest()
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(text) / 1e6:.2f} MB hlo, "
          f"{len(manifest['inputs'])} inputs, {len(manifest['outputs'])} outputs")


# ---------------------------------------------------------------------------
# Cross-layer numeric fixtures (python reference -> rust integration tests)
# ---------------------------------------------------------------------------

def _ref_adamw_train(model: Model, params, batches, hypers: Hypers, lr, steps):
    """Plain-jnp AdamW training loop (K=none), the rust split-engine oracle."""
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    losses = []
    loss_grad = jax.jit(jax.value_and_grad(model.loss))
    for t in range(1, steps + 1):
        x, y = batches[t - 1]
        loss, grads = loss_grad(params, x, y)
        grads, _ = optim_jax.global_norm_clip(grads, hypers.clip_norm)
        bc1 = 1.0 / (1.0 - hypers.beta1 ** t)
        bc2 = 1.0 / (1.0 - hypers.beta2 ** t)
        new_params = []
        for i, (spec, w, g) in enumerate(zip(model.specs, params, grads)):
            m[i] = hypers.beta1 * m[i] + (1 - hypers.beta1) * g
            v[i] = hypers.beta2 * v[i] + (1 - hypers.beta2) * g * g
            wd = hypers.weight_decay if spec.wd else 0.0
            new_params.append(
                w - lr * ((m[i] * bc1) / (jnp.sqrt(v[i] * bc2) + hypers.eps)
                          + wd * w))
        params = new_params
        losses.append(float(loss))
    return params, losses


def make_fixture(outdir, model_name, steps=5, lr=1e-3, seed=7):
    model = build_model(model_name)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, "mitchell")

    batches = []
    arrays = {}
    for t in range(steps):
        xs = []
        for (bname, shape, dt) in model.batch_specs:
            if dt == "s32":
                hi = model.meta.get("vocab", model.meta.get("classes", 2))
                arr = rng.integers(0, hi, size=shape).astype(np.int32)
            else:
                arr = rng.standard_normal(size=shape).astype(np.float32)
            arrays[f"{bname}{t}"] = arr
            xs.append(jnp.asarray(arr))
        batches.append(tuple(xs))

    final, losses = _ref_adamw_train(model, params, batches, LM_HYPERS, lr, steps)

    fixdir = os.path.join(outdir, "fixtures")
    os.makedirs(fixdir, exist_ok=True)
    np.savez(os.path.join(fixdir, f"{model_name}.params.npz"),
             **{s.name: np.asarray(p) for s, p in zip(model.specs, params)})
    np.savez(os.path.join(fixdir, f"{model_name}.batches.npz"), **arrays)
    meta = {
        "model": model_name, "steps": steps, "lr": lr,
        "hypers": {"beta1": LM_HYPERS.beta1, "beta2": LM_HYPERS.beta2,
                   "eps": LM_HYPERS.eps, "weight_decay": LM_HYPERS.weight_decay,
                   "clip_norm": LM_HYPERS.clip_norm},
        "losses": losses,
        "final_param_l2": float(jnp.sqrt(sum(jnp.sum(p * p) for p in final))),
    }
    with open(os.path.join(fixdir, f"{model_name}.fixture.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  fixture {model_name}: losses={['%.4f' % l for l in losses]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower a single artifact by name")
    ap.add_argument("--large", action="store_true",
                    help="also lower the ~124M gpt_small artifact")
    ap.add_argument("--skip-fixtures", action="store_true")
    ap.add_argument("--fixtures-only", action="store_true",
                    help="generate the numeric fixtures, skip HLO lowering")
    args = ap.parse_args(argv)

    os.makedirs(args.outdir, exist_ok=True)
    t0 = time.time()

    grads = [] if args.fixtures_only else (
        list(GRAD_MODELS) + (list(LARGE_GRAD_MODELS) if args.large else []))
    for name in grads:
        art = f"{name}.grad"
        if args.only and args.only not in (name, art):
            continue
        text, manifest = lower_grad_step(build_model(name))
        write_artifact(args.outdir, art, text, manifest)

    fused = [] if args.fixtures_only else list(FUSED)
    for (name, ruleset) in fused:
        art = f"{name}.train.{ruleset}"
        if args.only and args.only != art:
            continue
        text, manifest = lower_train_step(build_model(name), ruleset, LM_HYPERS)
        write_artifact(args.outdir, art, text, manifest)

    if not args.skip_fixtures and not args.only:
        make_fixture(args.outdir, "linear2_v64", steps=5, lr=1e-3)
        make_fixture(args.outdir, "gpt_nano", steps=3, lr=1e-3)
        # JAX mirror of the native interpreter's builtin mlp_tiny family:
        # replayed by rust/tests/fixture_replay.rs on the native backend.
        # The batches are random tokens, so the loss floor is ln(64); the
        # large lr makes every per-step loss a sharp function of the
        # accumulated AdamW state rather than a flat 4.1589 sequence.
        make_fixture(args.outdir, "native_mlp", steps=12, lr=1e-1)

    print(f"done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
