"""GPT-style decoder-only transformer (Radford et al. 2019 / nanoGPT style).

Matches the paper's App. B.1 architecture choices at reduced scale:
learnable positional embeddings, weight tying (Tok.Embd doubles as the LM
head), MLP upscaling factor 4, pre-LN blocks, no biases anywhere,
LayerNorm with weight only.

Parameter order (the manifest contract): tok_embd, pos_embd, then per
block [ln_attn, attn_q, attn_k, attn_v, attn_proj, ln_mlp, mlp_up,
mlp_down], then ln_final.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .common import (Model, ParamSpec, causal_attention, cross_entropy_lm,
                     layernorm, linear, normal, ones, uniform_fanin)


@dataclasses.dataclass
class GptConfig:
    name: str = "gpt_nano"
    n_layers: int = 4
    n_heads: int = 4
    d_model: int = 64
    vocab: int = 512
    ctx: int = 64
    mlp_factor: int = 4
    batch: int = 16

    @property
    def d_mlp(self):
        return self.mlp_factor * self.d_model


# Paper presets, width/depth-scaled for the CPU testbed (DESIGN.md §3).
PRESETS = {
    "gpt_nano": GptConfig("gpt_nano", 4, 4, 64, 512, 64, 4, 16),
    "gpt_nano_w192": GptConfig("gpt_nano_w192", 4, 4, 192, 512, 64, 4, 16),
    "gpt_mini": GptConfig("gpt_mini", 6, 6, 192, 2048, 128, 4, 8),
    # ~124M-param GPT-small analogue for the e2e `--large` preset.
    "gpt_small": GptConfig("gpt_small", 12, 12, 768, 50304, 1024, 4, 4),
}


def build(cfg: GptConfig) -> Model:
    d, v, t = cfg.d_model, cfg.vocab, cfg.ctx
    std = 0.02
    resid_std = std / (2 * cfg.n_layers) ** 0.5

    specs = [
        ParamSpec("tok_embd", (v, d), "tok_embd", -1,
                  normal(std), normal(1.0), wd=True),
        ParamSpec("pos_embd", (t, d), "pos_embd", -1,
                  normal(std), normal(1.0), wd=True),
    ]
    for l in range(cfg.n_layers):
        p = f"h{l}."
        specs += [
            ParamSpec(p + "ln_attn", (d,), "ln_attn", l, ones(), ones(), wd=False),
            ParamSpec(p + "attn_q", (d, d), "attn_q", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_k", (d, d), "attn_k", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_v", (d, d), "attn_v", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_proj", (d, d), "attn_proj", l,
                      normal(resid_std), uniform_fanin(d), wd=True),
            ParamSpec(p + "ln_mlp", (d,), "ln_mlp", l, ones(), ones(), wd=False),
            ParamSpec(p + "mlp_up", (cfg.d_mlp, d), "mlp_up", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "mlp_down", (d, cfg.d_mlp), "mlp_down", l,
                      normal(resid_std), uniform_fanin(cfg.d_mlp), wd=True),
        ]
    specs.append(ParamSpec("ln_final", (d,), "ln_final", -1,
                           ones(), ones(), wd=False))

    nl, nh = cfg.n_layers, cfg.n_heads

    def loss(params, x, y):
        it = iter(params)
        tok = next(it)
        pos = next(it)
        h = tok[x] + pos[None, : x.shape[1], :]
        for _ in range(nl):
            ln_a = next(it)
            wq, wk, wv, wp = next(it), next(it), next(it), next(it)
            ln_m = next(it)
            w_up, w_down = next(it), next(it)
            h = h + causal_attention(layernorm(h, ln_a), wq, wk, wv, wp, nh)
            z = linear(layernorm(h, ln_m), w_up)
            h = h + linear(_gelu(z), w_down)
        ln_f = next(it)
        h = layernorm(h, ln_f)
        logits = h @ tok.T  # weight tying: LM head = tok_embd
        return cross_entropy_lm(logits, y)

    batch_specs = [("x", (cfg.batch, t), "s32"), ("y", (cfg.batch, t), "s32")]
    meta = dataclasses.asdict(cfg) | {"family": "gpt", "tied": True}
    return Model(cfg.name, specs, loss, batch_specs, meta)


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 *
                                     (x + 0.044715 * x * x * x)))
