"""Vision Transformer (App. B.4): GPT-like blocks adapted for images with
patch embeddings and a learnable class token, Mitchell init, no biases.

Parameter order: patch_embd, pos_embd, cls_token, per block [ln_attn,
attn_q, attn_k, attn_v, attn_proj, ln_mlp, mlp_up, mlp_down], ln_final,
head.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .common import (Model, ParamSpec, bidirectional_attention,
                     cross_entropy_cls, layernorm, linear, normal, ones,
                     uniform_fanin)
from .gpt import _gelu


@dataclasses.dataclass
class VitConfig:
    name: str = "vit_mini_c10"
    n_layers: int = 4
    n_heads: int = 4
    d_model: int = 64
    img: int = 32
    patch: int = 4
    channels: int = 3
    classes: int = 10
    mlp_factor: int = 4
    batch: int = 32

    @property
    def d_mlp(self):
        return self.mlp_factor * self.d_model

    @property
    def n_patches(self):
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self):
        return self.patch * self.patch * self.channels


PRESETS = {
    "vit_mini_c10": VitConfig("vit_mini_c10", classes=10),
    "vit_mini_c100": VitConfig("vit_mini_c100", classes=100),
}


def build(cfg: VitConfig) -> Model:
    d = cfg.d_model
    std = 0.02
    resid_std = std / (2 * cfg.n_layers) ** 0.5
    seq = cfg.n_patches + 1  # + class token

    specs = [
        ParamSpec("patch_embd", (d, cfg.patch_dim), "patch_embd", -1,
                  normal(std), uniform_fanin(cfg.patch_dim), wd=True),
        ParamSpec("pos_embd", (seq, d), "pos_embd", -1,
                  normal(std), normal(1.0), wd=True),
        ParamSpec("cls_token", (d,), "cls_token", -1,
                  normal(std), normal(1.0), wd=False),
    ]
    for l in range(cfg.n_layers):
        p = f"h{l}."
        specs += [
            ParamSpec(p + "ln_attn", (d,), "ln_attn", l, ones(), ones(), wd=False),
            ParamSpec(p + "attn_q", (d, d), "attn_q", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_k", (d, d), "attn_k", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_v", (d, d), "attn_v", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_proj", (d, d), "attn_proj", l,
                      normal(resid_std), uniform_fanin(d), wd=True),
            ParamSpec(p + "ln_mlp", (d,), "ln_mlp", l, ones(), ones(), wd=False),
            ParamSpec(p + "mlp_up", (cfg.d_mlp, d), "mlp_up", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "mlp_down", (d, cfg.d_mlp), "mlp_down", l,
                      normal(resid_std), uniform_fanin(cfg.d_mlp), wd=True),
        ]
    specs += [
        ParamSpec("ln_final", (d,), "ln_final", -1, ones(), ones(), wd=False),
        ParamSpec("head", (cfg.classes, d), "head", -1,
                  normal(std), uniform_fanin(d), wd=True),
    ]

    nl, nh, ps = cfg.n_layers, cfg.n_heads, cfg.patch

    def loss(params, images, labels):
        it = iter(params)
        w_patch = next(it)
        pos = next(it)
        cls = next(it)
        b, hh, ww, c = images.shape
        gh, gw = hh // ps, ww // ps
        # (B, H, W, C) -> (B, gh*gw, ps*ps*C)
        x = images.reshape(b, gh, ps, gw, ps, c).transpose(0, 1, 3, 2, 4, 5)
        x = x.reshape(b, gh * gw, ps * ps * c)
        h = linear(x, w_patch)
        h = jnp.concatenate([jnp.broadcast_to(cls, (b, 1, h.shape[-1])), h], 1)
        h = h + pos[None, :, :]
        for _ in range(nl):
            ln_a = next(it)
            wq, wk, wv, wp = next(it), next(it), next(it), next(it)
            ln_m = next(it)
            w_up, w_down = next(it), next(it)
            h = h + bidirectional_attention(layernorm(h, ln_a), wq, wk, wv, wp, nh)
            z = linear(layernorm(h, ln_m), w_up)
            h = h + linear(_gelu(z), w_down)
        ln_f = next(it)
        w_head = next(it)
        h = layernorm(h, ln_f)
        logits = linear(h[:, 0, :], w_head)  # class token
        return cross_entropy_cls(logits, labels)

    batch_specs = [
        ("images", (cfg.batch, cfg.img, cfg.img, cfg.channels), "f32"),
        ("labels", (cfg.batch,), "s32"),
    ]
    meta = dataclasses.asdict(cfg) | {"family": "vit"}
    return Model(cfg.name, specs, loss, batch_specs, meta)
