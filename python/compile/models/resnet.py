"""ResNet (He et al. 2015) with BatchNorm for the image-classification SNR
experiments (§3.1.3). A width/depth-scaled ResNet-18 analogue: conv stem
followed by three stages of two basic blocks each, training-mode BatchNorm
(per-batch statistics; running stats are irrelevant to gradient/SNR
analysis), global average pooling and a linear classifier.

Conv weights are stored HWIO; the manifest's ``fan_out_axis = 3`` lets the
Rust analysis view them as (out_ch, kh*kw*in_ch) per the paper's fan
convention for convolutions.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (Model, ParamSpec, cross_entropy_cls, linear, normal,
                     ones, uniform_fanin, zeros)


@dataclasses.dataclass
class ResNetConfig:
    name: str = "resnet_mini_c10"
    stem: int = 16
    stages: tuple = (16, 32, 64)
    blocks_per_stage: int = 2
    img: int = 32
    channels: int = 3
    classes: int = 10
    batch: int = 32


PRESETS = {
    "resnet_mini_c10": ResNetConfig("resnet_mini_c10", classes=10),
    "resnet_mini_c100": ResNetConfig("resnet_mini_c100", classes=100),
}


def _conv_spec(name, kh, kw, cin, cout, depth):
    fan_in = kh * kw * cin
    he_std = (2.0 / fan_in) ** 0.5
    return ParamSpec(name, (kh, kw, cin, cout), "conv", depth,
                     normal(he_std), uniform_fanin(fan_in), wd=True,
                     fan_out_axis=3)


def conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return scale * (x - mu) / jnp.sqrt(var + eps) + bias


def build(cfg: ResNetConfig) -> Model:
    specs = [
        _conv_spec("stem.conv", 3, 3, cfg.channels, cfg.stem, -1),
        ParamSpec("stem.bn_scale", (cfg.stem,), "bn", -1, ones(), ones(), wd=False),
        ParamSpec("stem.bn_bias", (cfg.stem,), "bn", -1, zeros(), zeros(), wd=False),
    ]
    cin = cfg.stem
    depth = 0
    block_plan = []  # (prefix, cin, cout, stride, has_proj)
    for si, cout in enumerate(cfg.stages):
        for bi in range(cfg.blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            prefix = f"s{si}b{bi}."
            has_proj = (stride != 1) or (cin != cout)
            specs += [
                _conv_spec(prefix + "conv1", 3, 3, cin, cout, depth),
                ParamSpec(prefix + "bn1_scale", (cout,), "bn", depth,
                          ones(), ones(), wd=False),
                ParamSpec(prefix + "bn1_bias", (cout,), "bn", depth,
                          zeros(), zeros(), wd=False),
                _conv_spec(prefix + "conv2", 3, 3, cout, cout, depth),
                ParamSpec(prefix + "bn2_scale", (cout,), "bn", depth,
                          ones(), ones(), wd=False),
                ParamSpec(prefix + "bn2_bias", (cout,), "bn", depth,
                          zeros(), zeros(), wd=False),
            ]
            if has_proj:
                specs.append(_conv_spec(prefix + "proj", 1, 1, cin, cout, depth))
            block_plan.append((prefix, cin, cout, stride, has_proj))
            cin = cout
            depth += 1
    specs.append(ParamSpec("head", (cfg.classes, cin), "head", -1,
                           normal(0.02), uniform_fanin(cin), wd=True))

    plan = tuple(block_plan)

    def loss(params, images, labels):
        it = iter(params)
        h = conv2d(images, next(it))
        h = jax.nn.relu(batchnorm(h, next(it), next(it)))
        for (_prefix, _cin, _cout, stride, has_proj) in plan:
            w1, s1, b1 = next(it), next(it), next(it)
            w2, s2, b2 = next(it), next(it), next(it)
            shortcut = h
            z = jax.nn.relu(batchnorm(conv2d(h, w1, stride), s1, b1))
            z = batchnorm(conv2d(z, w2), s2, b2)
            if has_proj:
                shortcut = conv2d(h, next(it), stride)
            h = jax.nn.relu(z + shortcut)
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        logits = linear(h, next(it))
        return cross_entropy_cls(logits, labels)

    batch_specs = [
        ("images", (cfg.batch, cfg.img, cfg.img, cfg.channels), "f32"),
        ("labels", (cfg.batch,), "s32"),
    ]
    meta = dataclasses.asdict(cfg) | {"family": "resnet"}
    meta["stages"] = list(cfg.stages)
    return Model(cfg.name, specs, loss, batch_specs, meta)
