"""Simplified two-layer model for the vocabulary-size experiments (§4.1):
a token embedding matrix followed directly by a linear LM head (no
transformer blocks). The paper uses this model on WikiText-103 with BPE
vocab sweeps to show that heavy-tailed token distributions make the token
dimension incompressible.

App. B.2 init: embedding ~ truncated N(0, 1), head ~ truncated
N(0, 1/fan_in).
"""

from __future__ import annotations

import dataclasses

from .common import (Model, ParamSpec, cross_entropy_lm, trunc_normal)


@dataclasses.dataclass
class Linear2Config:
    name: str = "linear2_v256"
    vocab: int = 256
    d_model: int = 128
    ctx: int = 32
    batch: int = 16


# Vocab sweep presets (paper: 1k..65k on WikiText; scaled to the BPE'd
# repo corpus — DESIGN.md §3).
VOCABS = (64, 128, 256, 512, 1024, 2048, 4096)
PRESETS = {
    f"linear2_v{v}": Linear2Config(f"linear2_v{v}", vocab=v) for v in VOCABS
}


def build(cfg: Linear2Config) -> Model:
    v, d = cfg.vocab, cfg.d_model
    specs = [
        ParamSpec("tok_embd", (v, d), "tok_embd", -1,
                  trunc_normal(1.0), trunc_normal(1.0), wd=True),
        ParamSpec("lm_head", (v, d), "lm_head", -1,
                  trunc_normal(1.0 / d ** 0.5), trunc_normal(1.0 / d ** 0.5),
                  wd=True),
    ]

    def loss(params, x, y):
        tok, head = params
        h = tok[x]
        logits = h @ head.T
        return cross_entropy_lm(logits, y)

    batch_specs = [("x", (cfg.batch, cfg.ctx), "s32"),
                   ("y", (cfg.batch, cfg.ctx), "s32")]
    meta = dataclasses.asdict(cfg) | {"family": "linear2", "tied": False}
    return Model(cfg.name, specs, loss, batch_specs, meta)
