"""Llama-style transformer for the fine-tuning experiments (§3.1.2).

Architectural deltas vs. the GPT module that matter for the paper's SNR
analysis: RMSNorm instead of LayerNorm, a three-matrix gated MLP
(Up / Gate / Down, SiLU activation), untied LM head, and a vocabulary
that is large relative to d_model (the paper attributes the token
embedding's reduced SNR to exactly this ratio).

Parameter order: tok_embd, pos_embd, per block [rms_attn, attn_q, attn_k,
attn_v, attn_proj, rms_mlp, mlp_up, mlp_gate, mlp_down], rms_final,
lm_head.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (Model, ParamSpec, causal_attention, cross_entropy_lm,
                     linear, normal, ones, rmsnorm, uniform_fanin)


@dataclasses.dataclass
class LlamaConfig:
    name: str = "llama_tiny"
    n_layers: int = 4
    n_heads: int = 4
    d_model: int = 96
    vocab: int = 1024          # large vocab/d ratio, as in Llama-3.2
    ctx: int = 64
    mlp_factor: int = 3        # Llama-ish (8/3 rounded)
    batch: int = 16

    @property
    def d_mlp(self):
        return self.mlp_factor * self.d_model


PRESETS = {
    "llama_tiny": LlamaConfig(),
}


def build(cfg: LlamaConfig) -> Model:
    d, v, t = cfg.d_model, cfg.vocab, cfg.ctx
    std = 0.02
    resid_std = std / (2 * cfg.n_layers) ** 0.5

    specs = [
        ParamSpec("tok_embd", (v, d), "tok_embd", -1,
                  normal(std), normal(1.0), wd=True),
        ParamSpec("pos_embd", (t, d), "pos_embd", -1,
                  normal(std), normal(1.0), wd=True),
    ]
    for l in range(cfg.n_layers):
        p = f"h{l}."
        specs += [
            ParamSpec(p + "rms_attn", (d,), "ln_attn", l, ones(), ones(), wd=False),
            ParamSpec(p + "attn_q", (d, d), "attn_q", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_k", (d, d), "attn_k", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_v", (d, d), "attn_v", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "attn_proj", (d, d), "attn_proj", l,
                      normal(resid_std), uniform_fanin(d), wd=True),
            ParamSpec(p + "rms_mlp", (d,), "ln_mlp", l, ones(), ones(), wd=False),
            ParamSpec(p + "mlp_up", (cfg.d_mlp, d), "mlp_up", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "mlp_gate", (cfg.d_mlp, d), "mlp_gate", l,
                      normal(std), uniform_fanin(d), wd=True),
            ParamSpec(p + "mlp_down", (d, cfg.d_mlp), "mlp_down", l,
                      normal(resid_std), uniform_fanin(cfg.d_mlp), wd=True),
        ]
    specs += [
        ParamSpec("rms_final", (d,), "ln_final", -1, ones(), ones(), wd=False),
        ParamSpec("lm_head", (v, d), "lm_head", -1,
                  normal(std), uniform_fanin(d), wd=True),
    ]

    nl, nh = cfg.n_layers, cfg.n_heads

    def loss(params, x, y):
        it = iter(params)
        tok = next(it)
        pos = next(it)
        h = tok[x] + pos[None, : x.shape[1], :]
        for _ in range(nl):
            rms_a = next(it)
            wq, wk, wv, wp = next(it), next(it), next(it), next(it)
            rms_m = next(it)
            w_up, w_gate, w_down = next(it), next(it), next(it)
            h = h + causal_attention(rmsnorm(h, rms_a), wq, wk, wv, wp, nh)
            z = rmsnorm(h, rms_m)
            gated = jax.nn.silu(linear(z, w_gate)) * linear(z, w_up)
            h = h + linear(gated, w_down)
        rms_f = next(it)
        head = next(it)
        h = rmsnorm(h, rms_f)
        logits = h @ head.T
        return cross_entropy_lm(logits, y)

    batch_specs = [("x", (cfg.batch, t), "s32"), ("y", (cfg.batch, t), "s32")]
    meta = dataclasses.asdict(cfg) | {"family": "llama", "tied": False}
    return Model(cfg.name, specs, loss, batch_specs, meta)
