"""Layer-2 model definitions (JAX, build-time only).

Each model module exposes a ``build(cfg) -> Model`` where ``Model`` carries
the ordered parameter specs (the contract with the Rust runtime via the
artifact manifest) and a pure loss function over the flat parameter list.
"""

from .common import Model, ParamSpec  # noqa: F401
from . import gpt, llama, vit, resnet, linear2  # noqa: F401
