"""JAX mirror of the Rust native backend's builtin ``mlp_tiny`` family.

The native interpreter (rust/src/runtime/backend/native.rs) generates its
models in Rust, so unlike every other preset this one is never lowered to
HLO — it exists purely to produce the ``native_mlp`` numeric fixture that
``rust/tests/fixture_replay.rs`` replays through the interpreter's f64
path, pinning it to an external JAX ground truth.

Everything here must stay in lockstep with ``dims_for("mlp_tiny")`` and
``mlp_pass_l`` on the Rust side: same param names/shapes/order, same
per-token forward ``logits = W_head (W_down relu(W_up E[x]))``, same
mean-token cross entropy. The fixture carries the concrete initial
floats, so only shapes and forward semantics need to match — not RNG
streams.
"""

from __future__ import annotations

import dataclasses

import jax

from .common import Model, ParamSpec, cross_entropy_lm, linear, normal, uniform_fanin


@dataclasses.dataclass
class NativeMlpConfig:
    name: str = "native_mlp"
    vocab: int = 64
    d_model: int = 16
    hidden: int = 32
    ctx: int = 8
    batch: int = 8


PRESETS = {"native_mlp": NativeMlpConfig()}


def build(cfg: NativeMlpConfig) -> Model:
    v, d, h = cfg.vocab, cfg.d_model, cfg.hidden
    # init mirrors native.rs init_json: mitchell = N(0, 0.02^2) for every
    # matrix param (no 1-D params in this family)
    specs = [
        ParamSpec("tok_embd", (v, d), "tok_embd", -1,
                  normal(0.02), normal(1.0), wd=True),
        ParamSpec("mlp_up", (h, d), "mlp_up", 0,
                  normal(0.02), uniform_fanin(d), wd=True),
        ParamSpec("mlp_down", (d, h), "mlp_down", 0,
                  normal(0.02), uniform_fanin(h), wd=True),
        ParamSpec("lm_head", (v, d), "lm_head", 1,
                  normal(0.02), uniform_fanin(d), wd=True),
    ]

    def loss(params, x, y):
        tok, up, down, head = params
        emb = tok[x]                           # (B, T, D)
        u = jax.nn.relu(linear(emb, up))       # (B, T, H)
        z = linear(u, down)                    # (B, T, D)
        logits = linear(z, head)               # (B, T, V)
        return cross_entropy_lm(logits, y)

    batch_specs = [("x", (cfg.batch, cfg.ctx), "s32"),
                   ("y", (cfg.batch, cfg.ctx), "s32")]
    meta = dataclasses.asdict(cfg) | {"family": "mlp", "native_mirror": True}
    return Model(cfg.name, specs, loss, batch_specs, meta)
