"""Shared model plumbing: parameter specs, init metadata, core layers.

Conventions (mirrored by the Rust runtime — see rust/src/runtime/manifest.rs):

  * Linear weights are stored as ``(fan_out, fan_in)`` and applied as
    ``y = x @ W.T`` so axis 0 is always fan_out and axis 1 fan_in,
    matching the paper's K-notation (K=0 -> average over fan_out,
    K=1 -> average over fan_in).
  * Embeddings are stored as ``(vocab, d_model)``; axis 0 is the token
    dimension (the paper's incompressible dimension for Tok.Embd/LM Head).
  * Conv weights are stored HWIO ``(kh, kw, in_ch, out_ch)`` for
    ``lax.conv_general_dilated``; the manifest records
    ``fan_out_axis = 3`` so the analysis side views them as
    ``(out_ch, kh*kw*in_ch)``.
  * Every spec carries two init descriptions: ``init_mitchell``
    (Groeneveld et al. 2024: N(0, 0.02^2), residual-stream projections
    scaled by 1/sqrt(2*n_layers)) and ``init_default`` (PyTorch:
    U(-1/sqrt(fan_in), 1/sqrt(fan_in)) for linears, N(0,1) embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    layer_type: str          # tok_embd, pos_embd, lm_head, attn_q, ..., ln_final
    depth: int               # block index, or -1 for non-block params
    init_mitchell: dict      # {"scheme": .., ...}
    init_default: dict
    wd: bool                 # decoupled weight decay applies (2-D params)
    fan_out_axis: int = 0    # axis to treat as fan_out in the matrix view

    def to_json(self):
        return {
            "name": self.name,
            "shape": list(self.shape),
            "layer_type": self.layer_type,
            "depth": self.depth,
            "init_mitchell": self.init_mitchell,
            "init_default": self.init_default,
            "wd": self.wd,
            "fan_out_axis": self.fan_out_axis,
        }


@dataclasses.dataclass
class Model:
    name: str
    specs: list                      # [ParamSpec]
    loss: Callable                   # loss(params_list, *batch) -> scalar
    batch_specs: list                # [(name, shape, dtype_str)]
    meta: dict                       # model hyperparameters for the manifest

    def index(self, name: str) -> int:
        for i, s in enumerate(self.specs):
            if s.name == name:
                return i
        raise KeyError(name)

    def init_params(self, key, scheme: str = "mitchell"):
        """Build a concrete parameter list (used by tests and fixtures)."""
        params = []
        for spec in self.specs:
            key, sub = jax.random.split(key)
            init = spec.init_mitchell if scheme == "mitchell" else spec.init_default
            params.append(materialize_init(sub, spec.shape, init))
        return params


def materialize_init(key, shape, init):
    s = init["scheme"]
    if s == "zeros":
        return jnp.zeros(shape, jnp.float32)
    if s == "ones":
        return jnp.ones(shape, jnp.float32)
    if s == "normal":
        return init["std"] * jax.random.normal(key, shape, jnp.float32)
    if s == "uniform":
        lim = init["limit"]
        return jax.random.uniform(key, shape, jnp.float32, -lim, lim)
    if s == "trunc_normal":
        return init["std"] * jax.random.truncated_normal(
            key, -2.0, 2.0, shape, jnp.float32)
    raise ValueError(f"unknown init scheme {s!r}")


def normal(std):
    return {"scheme": "normal", "std": float(std)}


def uniform_fanin(fan_in):
    return {"scheme": "uniform", "limit": float(1.0 / (fan_in ** 0.5))}


def zeros():
    return {"scheme": "zeros"}


def ones():
    return {"scheme": "ones"}


def trunc_normal(std):
    return {"scheme": "trunc_normal", "std": float(std)}


# ---------------------------------------------------------------------------
# Core layers (pure functions over explicit weights)
# ---------------------------------------------------------------------------

def linear(x, w):
    """x: (..., fan_in), w: (fan_out, fan_in) -> (..., fan_out)."""
    return x @ w.T


def layernorm(x, weight, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return weight * (x - mu) / jnp.sqrt(var + eps)


def rmsnorm(x, weight, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return weight * x / jnp.sqrt(ms + eps)


def causal_attention(x, wq, wk, wv, wproj, n_heads):
    """Multi-head causal self-attention without biases.

    x: (B, T, D); wq/wk/wv/wproj: (D, D) stored (fan_out, fan_in).
    Heads are stacked along fan_out of wq/wk/wv — the dimension the paper
    finds incompressible for keys/queries.
    """
    b, t, d = x.shape
    hd = d // n_heads
    q = linear(x, wq).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    k = linear(x, wk).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    v = linear(x, wv).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return linear(y, wproj)


def bidirectional_attention(x, wq, wk, wv, wproj, n_heads):
    """ViT-style (unmasked) multi-head self-attention."""
    b, t, d = x.shape
    hd = d // n_heads
    q = linear(x, wq).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    k = linear(x, wk).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    v = linear(x, wv).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return linear(y, wproj)


def cross_entropy_lm(logits, targets):
    """Mean token-level cross entropy. logits (B,T,V), targets (B,T) i32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def cross_entropy_cls(logits, labels):
    """Mean class cross entropy. logits (B,C), labels (B,) i32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -jnp.mean(ll)


def params_dict(model: Model, params: Sequence):
    return {s.name: p for s, p in zip(model.specs, params)}
