//! SNR diagnostics (§3 + Discussion): probe the second-moment SNR of any
//! model along an Adam run and print the layer-type table the paper's
//! Figures 2-6 summarize — the "is my model compressible?" diagnostic a
//! practitioner would run before switching to a low-memory optimizer.
//!
//!     cargo run --release --example snr_probe -- --model vit_mini_c10

use anyhow::Result;

use slimadam::cli::Args;
use slimadam::coordinator::{run_config, TrainConfig};
use slimadam::rules::RuleSet;
use slimadam::snr::ProbeSchedule;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let model = args.str_or("model", "gpt_nano").to_string();
    let steps = args.usize_or("steps", 120)?;
    let lr = args.f64_or("lr", 1e-3)?;

    let vision = model.starts_with("vit") || model.starts_with("resnet");
    let mut cfg = if vision {
        TrainConfig::vision(&model, "adam", lr, steps)
    } else {
        TrainConfig::lm(&model, "adam", lr, steps)
    };
    cfg.probe = Some(ProbeSchedule::default());

    println!("probing {model} for {steps} steps at lr {lr:.0e} ...");
    let s = run_config(&cfg)?;
    let snr = s.snr.expect("probe enabled");

    println!("\nEq. 4 time-averaged SNR by layer type:");
    println!("{}", slimadam::exp::layer_type_table(&snr));

    let man = slimadam::exp::manifest(&model)?;
    for cutoff in [0.8, 1.0, 2.0] {
        let rules = RuleSet::derive(&snr, cutoff, format!("c{cutoff}"), Some(lr));
        println!(
            "cutoff {cutoff:>4}: {:3} tensors compressed -> {:.1}% of second moments saved",
            rules.rules.len(),
            100.0 * rules.saving(&man)
        );
    }
    Ok(())
}
