//! "DIY: Build Your Own Low-Memory Adam" (paper §5) in four steps:
//!
//! 1. probe Adam's second-moment SNR at a LOW learning rate (the paper's
//!    implicit-bias insight: rules derived at ~optimal/10 compress far
//!    more than rules derived at the optimal LR);
//! 2. derive compression rules with the SNR cutoff;
//! 3. train SlimAdam with those rules at the (large) optimal LR;
//! 4. compare against Adam: same loss, ~2% of the second moments.
//!
//!     cargo run --release --example diy_rules

use anyhow::Result;

use slimadam::coordinator::{run_config, TrainConfig};
use slimadam::rules::RuleSet;
use slimadam::snr::ProbeSchedule;

fn main() -> Result<()> {
    let model = "gpt_nano";
    let low_lr = 3e-4; // ~optimal/10 in this scaled setup
    let opt_lr = 3e-3;
    let steps = 100;

    // 1. probe at low LR
    println!("step 1: probing Adam SNR at low lr {low_lr:.0e}");
    let mut probe_cfg = TrainConfig::lm(model, "adam", low_lr, steps);
    probe_cfg.probe = Some(ProbeSchedule::default());
    let probed = run_config(&probe_cfg)?;
    let snr = probed.snr.expect("probe enabled");

    // 2. derive rules
    let rules = RuleSet::derive(&snr, 1.0, "diy", Some(low_lr));
    let man = slimadam::exp::manifest(model)?;
    println!(
        "step 2: derived {} rules -> {:.1}% of second moments saved",
        rules.rules.len(),
        100.0 * rules.saving(&man)
    );
    rules.save("results/diy.rules.json")?;

    // 3. train SlimAdam with the derived rules at the optimal LR
    println!("step 3: training SlimAdam at optimal lr {opt_lr:.0e}");
    let mut slim_cfg = TrainConfig::lm(model, "slimadam", opt_lr, steps);
    slim_cfg.ruleset = Some(rules);
    let slim = run_config(&slim_cfg)?;

    // 4. compare with Adam at the same LR
    println!("step 4: training Adam at the same lr");
    let adam = run_config(&TrainConfig::lm(model, "adam", opt_lr, steps))?;

    println!("\n===== DIY result =====");
    println!(
        "Adam      eval {:.4}  (v elements: {})",
        adam.result.eval_loss,
        adam.memory.as_ref().unwrap().v_elems
    );
    println!(
        "SlimAdam  eval {:.4}  (v elements: {}, saving {:.1}%)",
        slim.result.eval_loss,
        slim.memory.as_ref().unwrap().v_elems,
        100.0 * slim.memory.as_ref().unwrap().v_saving
    );
    println!(
        "Δeval = {:+.4} — rules derived at {low_lr:.0e} transfer to {opt_lr:.0e} \
         (the paper's §5 finding)",
        slim.result.eval_loss - adam.result.eval_loss
    );
    Ok(())
}
