//! End-to-end driver (the DESIGN.md §4 validation workload): train a GPT
//! on a *real* corpus — this repository's own source tree, BPE-tokenized —
//! for a few hundred steps, logging the loss curve, throughput, and the
//! optimizer-memory comparison.
//!
//!     make artifacts && cargo run --release --example train_gpt_e2e
//!
//! Flags:
//!     --model gpt_mini|gpt_nano|gpt_small   (gpt_small requires
//!         `python -m compile.aot --outdir artifacts --large` first; it is
//!         the paper's ~124M GPT-small and is CPU-expensive)
//!     --steps N       training steps (default 300)
//!     --optimizer X   adam | slimadam | ... (default slimadam)
//!     --lr F          peak LR (default 1e-3)
//!
//! All layers compose here: L1 Pallas fused-update semantics are validated
//! against this same optimizer math in pytest; L2's jax-lowered HLO
//! computes loss+grads; L3 owns data, schedule, optimizer and metrics.

use anyhow::Result;

use slimadam::cli::Args;
use slimadam::coordinator::{run_config, DataSpec, TrainConfig};
use slimadam::metrics::{ascii_chart, results_dir, JsonlWriter};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["large"])?;
    let model = args
        .str_or("model", if args.flag("large") { "gpt_small" } else { "gpt_mini" })
        .to_string();
    let steps = args.usize_or("steps", 300)?;
    let optimizer = args.str_or("optimizer", "slimadam").to_string();
    let lr = args.f64_or("lr", 1e-3)?;

    let mut cfg = TrainConfig::lm(&model, &optimizer, lr, steps);
    cfg.data = DataSpec::Corpus; // real data: the repo's own source tree
    cfg.eval_batches = 16;

    println!(
        "e2e: training {model} with {optimizer} on the repo-source corpus \
         ({steps} steps, lr {lr:.0e})"
    );
    let s = run_config(&cfg)?;

    // log the loss curve
    let dir = results_dir("e2e")?;
    let mut w = JsonlWriter::create(dir.join(format!("{model}.{optimizer}.loss.jsonl")))?;
    for &(step, loss) in &s.result.losses {
        let mut v = slimadam::json::Value::obj();
        v.set("step", step).set("loss", loss as f64);
        w.write(&v)?;
    }

    let pts: Vec<(f64, f64)> = s
        .result
        .losses
        .iter()
        .map(|&(t, l)| (t as f64, l as f64))
        .collect();
    println!(
        "\n{}",
        ascii_chart(
            &format!("{model} / {optimizer} — training loss"),
            &[("loss", &pts)],
            70,
            16,
            false,
            false
        )
    );

    println!(
        "final train loss {:.4}  (started {:.4})\n\
         held-out eval loss {:.4}\n\
         throughput {:.2} steps/s  ({:.1}s total)",
        s.result.final_train_loss,
        s.result.losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
        s.result.eval_loss,
        s.steps_per_s,
        s.result.wallclock_s
    );
    if let Some(m) = &s.memory {
        println!("{}", m.row());
    }
    anyhow::ensure!(!s.result.diverged, "e2e run diverged");
    anyhow::ensure!(
        s.result.final_train_loss
            < s.result.losses.first().map(|&(_, l)| l as f64).unwrap_or(0.0),
        "e2e run did not learn"
    );
    println!("\ne2e OK — loss curve written to {:?}", w.path);
    Ok(())
}
