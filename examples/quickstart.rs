//! Quickstart: train a tiny GPT with SlimAdam and compare its memory
//! footprint against Adam.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack: the AOT-lowered HLO artifact (JAX +
//! Pallas, compiled at build time) executes on the PJRT CPU client, while
//! the Rust optimizer family applies SlimAdam's SNR-derived compression
//! rules (paper Table 3).

use anyhow::Result;

use slimadam::coordinator::{run_config, TrainConfig};

fn main() -> Result<()> {
    // 1. Train with plain AdamW (the reference).
    let adam_cfg = TrainConfig::lm("gpt_nano", "adam", 1e-3, 60);
    println!("== training gpt_nano with Adam ==");
    let adam = run_config(&adam_cfg)?;

    // 2. Train with SlimAdam (paper Table-3 rules; 97% fewer second moments).
    let slim_cfg = TrainConfig::lm("gpt_nano", "slimadam", 1e-3, 60);
    println!("== training gpt_nano with SlimAdam ==");
    let slim = run_config(&slim_cfg)?;

    println!("\n===== results =====");
    for s in [&adam, &slim] {
        println!(
            "{:16} final train loss {:.4}  eval loss {:.4}  [{:.1} steps/s]",
            s.optimizer, s.result.final_train_loss, s.result.eval_loss, s.steps_per_s
        );
        if let Some(m) = &s.memory {
            println!("                 {}", m.row());
        }
    }
    let gap = slim.result.eval_loss - adam.result.eval_loss;
    println!(
        "\nSlimAdam matches Adam within Δeval = {gap:+.4} while storing {:.1}% \
         fewer second moments.",
        100.0 * slim.memory.as_ref().map(|m| m.v_saving).unwrap_or(0.0)
    );
    Ok(())
}
